"""Serving example: prefill + batched greedy decode with KV caches.

Loads a reduced qwen3-14b-family model, prefills a batch of prompts and
greedy-decodes continuations — the same serve_step the decode_32k /
long_500k dry-run shapes lower, here with a CPU-sized cache.  Also
demonstrates the sliding-window cache (the sub-quadratic long-context path).

  PYTHONPATH=src python examples/serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import greedy_generate


def main():
    cfg = get_smoke_config("qwen3_14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, n_new = 4, 32, 16

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = greedy_generate(model, params, {"tokens": prompts}, n_steps=n_new)
    dt = time.time() - t0
    print(f"arch={cfg.name}  batch={B}  prompt={S} tokens  "
          f"generated={n_new} tokens in {dt:.2f}s "
          f"({B * n_new / dt:.1f} tok/s on 1 CPU core)")
    for i in range(B):
        print(f"  req{i}: prompt[-4:]={prompts[i, -4:].tolist()} "
              f"-> {out[i].tolist()}")

    # sliding-window variant (window smaller than the prompt)
    model_w = build_model(cfg, decode_window=16)
    logits, caches = model_w.prefill(params, {"tokens": prompts})
    k_shape = jax.tree.leaves(caches)[0].shape
    print(f"\nsliding-window prefill: window=16, cache leaf shape {k_shape} "
          f"(ring buffer, vs full {S})")
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    from repro.serve import build_serve_step
    step = build_serve_step(model_w)
    nxt, caches = step(params, caches, tok, jnp.asarray(S, jnp.int32))
    print(f"one windowed decode step ok; next tokens {nxt[:, 0].tolist()}")


if __name__ == "__main__":
    main()
