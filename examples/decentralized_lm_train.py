"""End-to-end driver: decentralized EDM training of a ~100M-parameter LM.

Trains a 12-layer / d=768 llama-style model (≈108M params — smollm-family
reduced depth) across 4 decentralized agents on a ring, on synthetic
heterogeneous token streams (per-agent Dirichlet-tilted unigram over a shared
Markov backbone), with the full production train-step (vmap'd per-agent grads
→ EDM momentum/adapt/correct → ring gossip) and checkpointing.

  PYTHONPATH=src python examples/decentralized_lm_train.py            # demo
  PYTHONPATH=src python examples/decentralized_lm_train.py --steps 300 --full

This is the same `build_train_step` the 512-chip dry-run lowers; here it runs
on 1 CPU device with the agent axis unsharded.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train import build_train_step, checkpoint, init_state, make_topology


def lm_100m(full: bool) -> ModelConfig:
    return ModelConfig(
        name="edm-lm-108m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=24576, rope_theta=1e4,
        dtype="float32",
    ) if full else ModelConfig(
        name="edm-lm-11m", family="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=768, vocab_size=8192, rope_theta=1e4, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=1, help="per-agent batch")
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--beta", type=float, default=0.9)
    ap.add_argument("--algorithm", default="edm")
    ap.add_argument("--full", action="store_true",
                    help="use the ~108M-param config (slow on 1 CPU core)")
    ap.add_argument("--ckpt", default="/tmp/edm_lm.npz")
    args = ap.parse_args()

    cfg = lm_100m(args.full)
    model = build_model(cfg)
    n_p = cfg.n_params()
    print(f"model {cfg.name}: {n_p/1e6:.1f}M params, "
          f"{args.agents} agents on a ring")

    run = RunConfig(global_batch=args.agents * args.batch, seq_len=args.seq,
                    algorithm=args.algorithm, alpha=args.alpha, beta=args.beta,
                    topology="ring", remat=False)
    topo = make_topology(run, args.agents)
    print(f"topology: ring({args.agents})  lambda={topo.lam():.4f}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       n_agents=args.agents, phi=0.2)  # heterogeneous
    state = init_state(model, run, args.agents, jax.random.PRNGKey(0))
    step_fn = jax.jit(build_train_step(model, run, topo))

    key = jax.random.PRNGKey(1)
    t_start = time.time()
    for t in range(args.steps):
        key, kd = jax.random.split(key)
        batch = data.sample(kd, args.batch)
        state, metrics = step_fn(state, batch)
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t:4d}  loss={float(metrics['loss']):.4f}  "
                  f"consensus={float(metrics['consensus']):.3e}  "
                  f"|g|={float(metrics['grad_norm']):.3f}  "
                  f"({(time.time()-t_start):.1f}s)", flush=True)

    checkpoint.save(args.ckpt, state["params"])
    print(f"saved agent-replica params to {args.ckpt}")
    restored = checkpoint.load(args.ckpt, state["params"])
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(restored),
                               jax.tree.leaves(state["params"])))
    print(f"checkpoint roundtrip max|Δ| = {diff:.1e}")


if __name__ == "__main__":
    main()
