"""Quickstart — the paper in 60 seconds.

Runs EDM vs DmSGD on the paper's §E.1 quadratic problem over a sparse ring
of 32 agents with strong data heterogeneity and full-batch gradients (σ=0).
EDM (bias-corrected) reaches the exact optimum; DmSGD stalls at the
heterogeneity floor (Proposition 2 of Yuan et al. 2021, quoted in the paper).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import make_mixer, make_optimizer, ring
from repro.data import quadratic_problem


def main():
    n = 32
    topo = ring(n)
    print(f"ring({n}): lambda = {topo.lam():.4f}  spectral gap = "
          f"{topo.spectral_gap():.4f}")
    stoch, full, x_opt, zeta2 = quadratic_problem(n, c=1.0, sigma=0.0, seed=0)
    print(f"data heterogeneity  zeta^2 = {zeta2:.2f}\n")

    mix = make_mixer(topo)
    for alg in ("edm", "dmsgd"):
        opt = make_optimizer(alg, alpha=0.05, beta=0.9, mix=mix)
        x = jnp.zeros((n, x_opt.shape[0]))
        state = opt.init(x)
        print(f"--- {alg} ---")
        for t in range(3001):
            x, state = opt.step(x, full(x), state)
            if t % 500 == 0:
                err = float(jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1)))
                print(f"  step {t:5d}  mean ||x_i - x*||^2 = {err:.3e}")
        print()


if __name__ == "__main__":
    main()
