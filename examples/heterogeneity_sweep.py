"""Paper-figure reproduction driver: sweeps heterogeneity and prints the
Fig-1-style comparison table for all 8 implemented algorithms.

  PYTHONPATH=src python examples/heterogeneity_sweep.py
"""
import jax.numpy as jnp

from repro.core import ALGORITHMS, make_mixer, make_optimizer, ring
from repro.data import quadratic_problem


def main():
    n, steps = 32, 3000
    topo = ring(n)
    print(f"ring({n})  lambda={topo.lam():.4f}   (paper Fig. 1 setup)\n")
    header = f"{'zeta^2':>10s} " + " ".join(f"{a:>10s}" for a in sorted(ALGORITHMS))
    print(header)
    for c in (100.0, 3.0, 1.0, 0.3):
        stoch, full, x_opt, zeta2 = quadratic_problem(n, c=c, sigma=0.05,
                                                      seed=0)
        row = [f"{zeta2:10.3f}"]
        for alg in sorted(ALGORITHMS):
            mix = make_mixer(topo)
            opt = make_optimizer(alg, alpha=0.05, beta=0.9, mix=mix)
            x = jnp.zeros((n, x_opt.shape[0]))
            state = opt.init(x)
            import jax
            key = jax.random.PRNGKey(0)

            @jax.jit
            def body(carry, k):
                x, st = carry
                x, st = opt.step(x, stoch(x, k), st)
                return (x, st), None

            (x, state), _ = jax.lax.scan(body, (x, state),
                                         jax.random.split(key, steps))
            err = float(jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1)))
            row.append(f"{err:10.2e}")
        print(" ".join(row))
    print("\nEDM/ED floors are flat in zeta^2; DmSGD-family floors grow ~ zeta^2.")


if __name__ == "__main__":
    main()
