"""Shared harness for the paper-reproduction benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import Topology, make_mixer, make_optimizer

__all__ = ["run_algorithm", "timeit_us", "csv_row"]


def run_algorithm(alg: str, grad_fn: Callable, x0, topo: Topology, *,
                  alpha: float, beta: float = 0.9, steps: int, seed: int = 0,
                  eval_every: int = 10,
                  eval_fn: Optional[Callable] = None) -> Dict[str, jnp.ndarray]:
    """Run a decentralized algorithm; grad_fn(x, key) -> per-agent grads.

    Returns {"xs": final params, "metric": (steps//eval_every,) eval series}.
    """
    mix = make_mixer(topo)
    opt = make_optimizer(alg, alpha=alpha, beta=beta, mix=mix)
    state = opt.init(x0)

    def body(carry, key):
        x, st = carry
        g = grad_fn(x, key)
        x, st = opt.step(x, g, st)
        m = eval_fn(x) if eval_fn is not None else jnp.zeros(())
        return (x, st), m

    @jax.jit
    def run(x0, state, keys):
        (x, st), ms = jax.lax.scan(body, (x0, state), keys)
        return x, ms

    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    x, ms = run(x0, state, keys)
    return {"x": x, "metric": ms[::eval_every]}


def timeit_us(fn: Callable, *args, iters: int = 20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
