"""Microbenchmarks of the gossip/optimizer hot path (CPU wall-clock; the
derived column carries the analytically modeled TPU HBM-traffic ratio).

Three parts:

* in-process engine benches on the current device set (dense vs shifts,
  EDM step fused vs unfused);
* an engine × topology × fused sweep (``--sweep``) that needs one device
  per agent — ``run()`` launches it in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=32`` so it works
  regardless of the parent's device count.  This is the acceptance bench
  for the production ppermute path: on the paper's n=32 ring the
  fused-combine ppermute engine must come in at ≤ the shifts engine;
* an engine × *schedule* sweep (``--schedule``, DESIGN §4) reporting
  per-step wall time AND per-step wire bytes (the model from
  ``repro.core.schedule.wire_bytes_per_step``) for the static exp graph vs
  the one-peer round-robin schedule vs alternating hierarchical — including
  the blocked A=32-on-8-devices ppermute case.  Results land in
  ``BENCH_gossip.json`` at the repo root (the bench trajectory artifact CI
  uploads).

CLI::

    python -m benchmarks.gossip_micro --schedule round_robin --steps 8
    python -m benchmarks.gossip_micro --schedule all --block-rows 256
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

import jax

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO, "BENCH_gossip.json")
_SWEEP_MARKER = "SWEEP_CSV_JSON:"
_SCHED_MARKER = "SCHED_JSON:"


def _sweep_cases():
    from repro.core import hierarchical, ring
    return [
        ("ring32", ring(32), 1),
        ("hier2x16", hierarchical(2, 16), 2),
        ("hier4x4_ring", hierarchical(4, 4, intra="ring"), 4),
    ]


def sweep(d: int = 1 << 16, iters: int = 20) -> List[str]:
    """Engine × topology × fused sweep; requires >= 32 devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_mixer
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import csv_row, timeit_us

    lines: List[str] = []
    for name, topo, pods in _sweep_cases():
        A = topo.n_agents
        mesh = make_gossip_mesh(A, pods=pods)
        axes = gossip_agent_axes(mesh)
        x = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(0), (A, d)),
            NamedSharding(mesh, P(axes)))
        engines = {
            "shifts": make_mixer(topo, "shifts"),
            "ppermute": make_mixer(topo, "ppermute", mesh=mesh,
                                   agent_axes=axes),
            "ppermute_fused": make_mixer(topo, "ppermute", mesh=mesh,
                                         agent_axes=axes,
                                         use_fused_kernel=True),
        }
        us_shifts = None
        for ename, mixer in engines.items():
            us = timeit_us(jax.jit(mixer), x, iters=iters)
            if ename == "shifts":
                us_shifts = us
            lines.append(csv_row(
                f"gossip/{name}/{ename}", us,
                f"n={A};d={d};terms={len(topo.terms)};"
                f"speedup_vs_shifts={us_shifts / us:.2f}x"))
    return lines


def _schedule_cases(which: str):
    from repro.core import (AlternatingHierarchical, RoundRobinExp,
                            StaticSchedule, exp_graph)
    cases = {
        "static": StaticSchedule(exp_graph(32)),
        "round_robin": RoundRobinExp(32),
        "alt_hier": AlternatingHierarchical(4, 8),
    }
    if which != "all":
        cases = {which: cases[which]}
    return cases


def schedule_sweep(which: str = "all", steps: int = 8, d: int = 1 << 16,
                   iters: int = 20, block_rows: int = 0) -> List[dict]:
    """Engine × schedule sweep: us/step and wire bytes/step over ``steps``
    consecutive schedule steps (each distinct round is compiled and timed
    once, then weighted by how often it occurs in the window — so steps=8
    over a period-5 schedule weights rounds 0–2 twice).

    Needs 32 host devices.  The blocked config packs the 32 agents onto 8
    devices (B = 4) — the multi-agent-per-device path.  ``block_rows``
    reaches the fused kernel via REPRO_BLOCK_ROWS, which the parent process
    exports before this subprocess imports the kernels; the recorded value
    is the effective one.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_schedule_mixer, wire_bytes_per_step
    from repro.kernels.edm_update import BLOCK_ROWS
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import timeit_us

    if block_rows:
        assert block_rows == BLOCK_ROWS, \
            (block_rows, BLOCK_ROWS, "REPRO_BLOCK_ROWS not exported?")
    results = []
    for sname, sched in _schedule_cases(which).items():
        A = sched.n_agents
        configs = {
            "shifts": dict(engine="shifts", apd=1),
            "ppermute": dict(engine="ppermute", apd=1),
            "ppermute_fused": dict(engine="ppermute", apd=1, fused=True),
            "ppermute_fused_b4": dict(engine="ppermute", apd=4, fused=True),
        }
        for cname, c in configs.items():
            apd = c["apd"]
            mesh = axes = None
            if c["engine"] == "ppermute":
                mesh = make_gossip_mesh(A, agents_per_device=apd)
                axes = gossip_agent_axes(mesh)
            mix = make_schedule_mixer(sched, c["engine"], mesh=mesh,
                                      agent_axes=axes,
                                      use_fused_kernel=c.get("fused", False))
            x = jax.random.normal(jax.random.PRNGKey(0), (A, d))
            if mesh is not None:
                x = jax.device_put(x, NamedSharding(mesh, P(axes)))
            # one jitted application per distinct round (concrete step →
            # no switch), weighted over the `steps`-step window
            us_round = {r: timeit_us(jax.jit(lambda t, r=r: mix(t, step=r)),
                                     x, iters=max(iters // sched.period, 2))
                        for r in range(sched.period)}
            us = sum(us_round[t % sched.period] for t in range(steps)) / steps
            wire = sum(wire_bytes_per_step(sched, t, elems_per_agent=d,
                                           agents_per_device=apd,
                                           engine=c["engine"])
                       for t in range(steps)) / steps
            results.append({
                "schedule": sname, "config": cname, "engine": c["engine"],
                "agents": A, "agents_per_device": apd, "d": d,
                "period": sched.period, "steps": steps,
                "block_rows": BLOCK_ROWS,
                "us_per_step": round(us, 1),
                "wire_bytes_per_step": int(wire),
                "permutes_per_step": max(
                    sum(1 for t in rnd.terms if t.shift != 0)
                    for rnd in sched.rounds),
            })
    return results


def _schedule_subprocess(which: str, steps: int,
                         block_rows: int = 0) -> List[dict]:
    """Run :func:`schedule_sweep` under a 32-device host platform."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=32",
           "PYTHONPATH": os.path.join(REPO, "src")
           + (os.pathsep + os.environ["PYTHONPATH"]
              if os.environ.get("PYTHONPATH") else "")}
    if block_rows:
        env["REPRO_BLOCK_ROWS"] = str(block_rows)
    r = subprocess.run([sys.executable, "-m", "benchmarks.gossip_micro",
                        "--schedule-inner", which, "--steps", str(steps),
                        "--block-rows", str(block_rows)],
                       cwd=REPO, env=env, capture_output=True, text=True,
                       timeout=900)
    for line in r.stdout.splitlines():
        if line.startswith(_SCHED_MARKER):
            return json.loads(line[len(_SCHED_MARKER):])
    raise RuntimeError(f"schedule sweep failed:\n{r.stdout[-2000:]}"
                       f"\n{r.stderr[-2000:]}")


def _sched_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"gossip_sched/{row['schedule']}/{row['config']}",
        row["us_per_step"],
        f"n={row['agents']};B={row['agents_per_device']};"
        f"wire_bytes={row['wire_bytes_per_step']};"
        f"permutes={row['permutes_per_step']}") for row in rows]


def write_bench_json(results: List[dict]) -> str:
    """Persist the schedule sweep to BENCH_gossip.json at the repo root."""
    payload = {
        "bench": "gossip_schedule_sweep",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_JSON


def _sweep_subprocess() -> List[str]:
    """Run :func:`sweep` under a 32-device host platform (one per agent)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=32",
           "PYTHONPATH": os.path.join(REPO, "src")
           + (os.pathsep + os.environ["PYTHONPATH"]
              if os.environ.get("PYTHONPATH") else "")}
    r = subprocess.run([sys.executable, "-m", "benchmarks.gossip_micro",
                        "--sweep"], cwd=REPO, env=env, capture_output=True,
                       text=True, timeout=900)
    for line in r.stdout.splitlines():
        if line.startswith(_SWEEP_MARKER):
            return json.loads(line[len(_SWEEP_MARKER):])
    raise RuntimeError(f"engine sweep failed:\n{r.stdout[-2000:]}"
                       f"\n{r.stderr[-2000:]}")


def run(verbose: bool = True) -> Dict:
    from repro.core import make_mixer, ring
    from repro.core.optimizers import make_edm
    from .common import csv_row, timeit_us

    results: Dict = {}
    lines = []
    topo = ring(8)
    d = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (8, d))

    mix_dense = jax.jit(make_mixer(topo, "dense"))
    mix_shift = jax.jit(make_mixer(topo, "shifts"))
    us_d = timeit_us(mix_dense, x)
    us_s = timeit_us(mix_shift, x)
    lines.append(csv_row("gossip/dense_W", us_d, f"n=8;d={d}"))
    lines.append(csv_row("gossip/shift_rolls", us_s,
                         f"n=8;d={d};speedup_vs_dense={us_d / us_s:.2f}x"))

    # EDM unfused vs fused-kernel step (interpret-mode Pallas on CPU — the
    # derived column reports the modeled HBM-stream ratio, which is what
    # matters on TPU: unfused ≈ 11 streams vs fused 7).
    params = {"w": x}
    grads = {"w": 0.1 * x}
    o_un = make_edm(0.05, 0.9, make_mixer(topo), use_fused_kernel=False)
    st = o_un.init(params)
    step_un = jax.jit(lambda p, g, s: o_un.step(p, g, s))
    us_un = timeit_us(step_un, params, grads, st)
    lines.append(csv_row("edm_step/unfused_jnp", us_un,
                         "hbm_streams=11(x,g,m,psi->m,psi,phi + mix)"))
    lines.append(csv_row("edm_step/fused_pallas", float("nan"),
                         "hbm_streams=7;modeled_traffic_ratio=0.64;"
                         "validated=interpret_mode"))

    # engine × topology × fused sweep, one device per agent
    try:
        lines.extend(_sweep_subprocess())
    except Exception as e:  # pragma: no cover - environment-dependent
        lines.append(csv_row("gossip/engine_sweep", float("nan"),
                             f"skipped:{type(e).__name__}"))
        if verbose:
            print(f"  [engine sweep skipped: {e}]")

    # engine × schedule sweep (static vs round_robin vs alt_hier) + wire bytes
    try:
        sched_rows = _schedule_subprocess("all", steps=8)
        lines.extend(_sched_csv_rows(sched_rows))
        results["bench_json"] = write_bench_json(sched_rows)
        if verbose:
            print(f"  [schedule sweep -> {results['bench_json']}]")
    except Exception as e:  # pragma: no cover - environment-dependent
        lines.append(csv_row("gossip_sched/sweep", float("nan"),
                             f"skipped:{type(e).__name__}"))
        if verbose:
            print(f"  [schedule sweep skipped: {e}]")

    results["csv"] = lines
    if verbose:
        print("\n".join("  " + l for l in lines))
    return results


def _cli() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="(inner) engine×topology sweep; needs 32 devices")
    ap.add_argument("--schedule-inner", default=None,
                    help="(inner) engine×schedule sweep; needs 32 devices")
    ap.add_argument("--schedule", default=None,
                    choices=["static", "round_robin", "alt_hier", "all"],
                    help="run the engine×schedule sweep (in a 32-device "
                         "subprocess) and write BENCH_gossip.json")
    ap.add_argument("--steps", type=int, default=8,
                    help="steps per schedule config")
    ap.add_argument("--block-rows", type=int, default=0,
                    help="Pallas BLOCK_ROWS override for the fused combine "
                         "(0 = REPRO_BLOCK_ROWS / default)")
    args = ap.parse_args()

    if args.sweep:
        print(_SWEEP_MARKER + json.dumps(sweep()))
    elif args.schedule_inner:
        print(_SCHED_MARKER + json.dumps(schedule_sweep(
            args.schedule_inner, steps=args.steps,
            block_rows=args.block_rows)))
    elif args.schedule:
        rows = _schedule_subprocess(args.schedule, steps=args.steps,
                                    block_rows=args.block_rows)
        print("\n".join(_sched_csv_rows(rows)))
        print(f"wrote {write_bench_json(rows)}")
    else:
        print("\n".join(run()["csv"]))


if __name__ == "__main__":
    _cli()
