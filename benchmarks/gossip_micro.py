"""Microbenchmarks of the gossip/optimizer hot path (CPU wall-clock; the
derived column carries the analytically modeled TPU HBM-traffic ratio)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import make_mixer, ring
from repro.core.optimizers import make_edm
from .common import csv_row, timeit_us


def run(verbose: bool = True) -> Dict:
    results: Dict = {}
    lines = []
    topo = ring(8)
    d = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (8, d))

    mix_dense = jax.jit(make_mixer(topo, "dense"))
    mix_shift = jax.jit(make_mixer(topo, "shifts"))
    us_d = timeit_us(mix_dense, x)
    us_s = timeit_us(mix_shift, x)
    lines.append(csv_row("gossip/dense_W", us_d, f"n=8;d={d}"))
    lines.append(csv_row("gossip/shift_rolls", us_s,
                         f"n=8;d={d};speedup_vs_dense={us_d / us_s:.2f}x"))

    # EDM unfused vs fused-kernel step (interpret-mode Pallas on CPU — the
    # derived column reports the modeled HBM-stream ratio, which is what
    # matters on TPU: unfused ≈ 11 streams vs fused 7).
    params = {"w": x}
    grads = {"w": 0.1 * x}
    o_un = make_edm(0.05, 0.9, make_mixer(topo), use_fused_kernel=False)
    st = o_un.init(params)
    step_un = jax.jit(lambda p, g, s: o_un.step(p, g, s))
    us_un = timeit_us(step_un, params, grads, st)
    lines.append(csv_row("edm_step/unfused_jnp", us_un,
                         "hbm_streams=11(x,g,m,psi->m,psi,phi + mix)"))
    lines.append(csv_row("edm_step/fused_pallas", float("nan"),
                         "hbm_streams=7;modeled_traffic_ratio=0.64;"
                         "validated=interpret_mode"))
    results["csv"] = lines
    if verbose:
        print("\n".join("  " + l for l in lines))
    return results


if __name__ == "__main__":
    print("\n".join(run()["csv"]))
