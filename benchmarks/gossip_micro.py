"""Microbenchmarks of the gossip/optimizer hot path (CPU wall-clock; the
derived column carries the analytically modeled TPU HBM-traffic ratio).

Three parts:

* in-process engine benches on the current device set (dense vs shifts,
  EDM step fused vs unfused);
* an engine × topology × fused sweep (``--sweep``) that needs one device
  per agent — ``run()`` launches it in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=32`` so it works
  regardless of the parent's device count.  This is the acceptance bench
  for the production ppermute path: on the paper's n=32 ring the
  fused-combine ppermute engine must come in at ≤ the shifts engine;
* an engine × *schedule* sweep (``--schedule``, DESIGN §4) reporting
  per-step wall time AND per-step wire bytes (the model from
  ``repro.core.schedule.wire_bytes_per_step``, now in both logical and
  ``_pack``-padded flavors — the padded column is what a packed payload
  actually ships) for the static exp graph vs the one-peer round-robin
  schedule vs alternating hierarchical — including the blocked
  A=32-on-8-devices ppermute case.  Results land in ``BENCH_gossip.json``
  at the repo root (the bench trajectory artifact CI uploads);
* an end-to-end EDM *step* sweep (``--e2e-step``, DESIGN §5): leaf-wise vs
  bus-resident full EDM steps (per-agent grads synthesized) across model
  sizes, reporting us/step, permutes/step, kernel launches/step and
  modeled HBM bytes padded vs logical for both paths, plus a numerical
  equivalence gate (bus vs leaf-wise on a smoke transformer — nonzero exit
  on divergence, the CI contract).  Results land in ``BENCH_edm_step.json``.
  The same sweep also times the **overlapped gossip pipeline**
  (DESIGN §6): ``overlap="delayed"`` vs the synchronous bus step per size,
  with the measured gossip-only us/step and the fraction of it the
  pipeline hides, written to ``BENCH_overlap.json`` — together with the
  delayed-vs-synchronous **loss-divergence gates** (trajectory envelope on
  the smoke transformer inside the sweep, plus the §E.1 quadratic and
  §E.2 logistic problems under a dense-oracle W; any gate failure raises,
  the CI contract);
* a BLOCK_ROWS autotune (``--autotune-block-rows``): sweeps the kernel
  grid-tile height over {128, 256, 512, 1024} for the fused EDM update and
  the 3-ary gossip combine across bus sizes and prints the argmin per size
  (the ROADMAP "tune BLOCK_ROWS" knob; wall-clock is interpret-mode on CPU
  — re-run on a real TPU for the production number);
* a **sharded vs gathered** gossip sweep (``--sharded``, DESIGN §7): the
  row-sharded ``P('pod', 'data')`` bus vs the rows-replicated pre-§7
  layout on a 2-pod × 4-shard host mesh — us/step and wire bytes/step
  (per-device permute payload drops by the shard factor), with the
  sharded == dense-oracle equivalence gate raising on divergence (the CI
  contract of the ``pod-fsdp-smoke`` job).  Results land in
  ``BENCH_shard.json``;
* a **quantized-wire** sweep (``--wire``, DESIGN §9): f32 vs bf16 vs int8
  gossip wire on an 8-agent host ring — us/step, codec-derived wire
  bytes/step (every byte column in this module now derives from the wire
  codec's ``payload_bytes`` instead of a hardcoded 4 B/elem) and the
  ``compression_ratio`` column, behind oracle/masked/sharded equivalence
  gates; plus the modeled n=32 byte cut (bf16 ≥ 2×, int8 ≥ 3.5× at an
  unchanged permute count) and the §E.1/§E.2 error-feedback divergence
  gates with naive-quantization negative-control rows.  Results land in
  ``BENCH_wire.json``;
* a **policy-group** sweep (``--groups``, DESIGN §12): per
  ``--gossip-groups`` config (ungrouped baseline, 2-group all-gossip,
  expert opt-out, expert slow-cycle) on the smoke MoE transformer —
  group-mixer us/step and the modeled per-group wire bytes over an
  8-step window, behind the segment-composition gates (2-group
  all-gossip == whole-bus mixer bit-exactly; opt-out expert rows come
  back untouched) and the byte-accounting gates (opt-out strictly under
  the baseline; all-gossip − opt-out delta == the experts group's
  modeled bytes exactly).  Results land in ``BENCH_groups.json``.

CLI::

    python -m benchmarks.gossip_micro --schedule round_robin --steps 8
    python -m benchmarks.gossip_micro --schedule all --block-rows 256
    python -m benchmarks.gossip_micro --e2e-step
    python -m benchmarks.gossip_micro --autotune-block-rows
    python -m benchmarks.gossip_micro --sharded
    python -m benchmarks.gossip_micro --wire
    python -m benchmarks.gossip_micro --groups
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

import jax

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO, "BENCH_gossip.json")
BENCH_EDM_JSON = os.path.join(REPO, "BENCH_edm_step.json")
BENCH_OVERLAP_JSON = os.path.join(REPO, "BENCH_overlap.json")
BENCH_SHARD_JSON = os.path.join(REPO, "BENCH_shard.json")
BENCH_ELASTIC_JSON = os.path.join(REPO, "BENCH_elastic.json")
BENCH_WIRE_JSON = os.path.join(REPO, "BENCH_wire.json")
BENCH_GROUPS_JSON = os.path.join(REPO, "BENCH_groups.json")
_SWEEP_MARKER = "SWEEP_CSV_JSON:"
_SCHED_MARKER = "SCHED_JSON:"
_E2E_MARKER = "E2E_JSON:"
_SHARD_MARKER = "SHARD_JSON:"
_ELASTIC_MARKER = "ELASTIC_JSON:"
_WIRE_MARKER = "WIRE_JSON:"
_GROUPS_MARKER = "GROUPS_JSON:"


def _sweep_cases():
    from repro.core import hierarchical, ring
    return [
        ("ring32", ring(32), 1),
        ("hier2x16", hierarchical(2, 16), 2),
        ("hier4x4_ring", hierarchical(4, 4, intra="ring"), 4),
    ]


def sweep(d: int = 1 << 16, iters: int = 20) -> List[str]:
    """Engine × topology × fused sweep; requires >= 32 devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_mixer
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import csv_row, timeit_us

    lines: List[str] = []
    for name, topo, pods in _sweep_cases():
        A = topo.n_agents
        mesh = make_gossip_mesh(A, pods=pods)
        axes = gossip_agent_axes(mesh)
        x = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(0), (A, d)),
            NamedSharding(mesh, P(axes)))
        engines = {
            "shifts": make_mixer(topo, "shifts"),
            "ppermute": make_mixer(topo, "ppermute", mesh=mesh,
                                   agent_axes=axes),
            "ppermute_fused": make_mixer(topo, "ppermute", mesh=mesh,
                                         agent_axes=axes,
                                         use_fused_kernel=True),
        }
        us_shifts = None
        for ename, mixer in engines.items():
            us = timeit_us(jax.jit(mixer), x, iters=iters)
            if ename == "shifts":
                us_shifts = us
            lines.append(csv_row(
                f"gossip/{name}/{ename}", us,
                f"n={A};d={d};terms={len(topo.terms)};"
                f"speedup_vs_shifts={us_shifts / us:.2f}x"))
    return lines


def _schedule_cases(which: str):
    from repro.core import (AlternatingHierarchical, RoundRobinExp,
                            StaticSchedule, exp_graph)
    cases = {
        "static": StaticSchedule(exp_graph(32)),
        "round_robin": RoundRobinExp(32),
        "alt_hier": AlternatingHierarchical(4, 8),
    }
    if which != "all":
        cases = {which: cases[which]}
    return cases


def schedule_sweep(which: str = "all", steps: int = 8, d: int = 1 << 16,
                   iters: int = 20, block_rows: int = 0,
                   wire_fmt: str = "f32") -> List[dict]:
    """Engine × schedule sweep: us/step and wire bytes/step over ``steps``
    consecutive schedule steps (each distinct round is compiled and timed
    once, then weighted by how often it occurs in the window — so steps=8
    over a period-5 schedule weights rounds 0–2 twice).

    Needs 32 host devices.  The blocked config packs the 32 agents onto 8
    devices (B = 4) — the multi-agent-per-device path.  ``block_rows``
    reaches the fused kernel via REPRO_BLOCK_ROWS, which the parent process
    exports before this subprocess imports the kernels; the recorded value
    is the effective one.  ``wire_fmt`` selects the modeled wire format
    (DESIGN §9): the wire-bytes column derives from the codec's payload
    bytes (bf16 = 2 B/elem, int8 = 1 B/elem + per-block scales) instead of
    the pre-§9 hardcoded 4 B/elem; the timed mixers stay f32 here — the
    quantized engines are timed and gated by :func:`wire_sweep`.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_schedule_mixer, wire_bytes_per_step
    from repro.core.wire import make_codec
    from repro.kernels.edm_update import BLOCK_ROWS
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import timeit_us

    if block_rows:
        assert block_rows == BLOCK_ROWS, \
            (block_rows, BLOCK_ROWS, "REPRO_BLOCK_ROWS not exported?")
    codec = make_codec(wire_fmt, 8)
    results = []
    for sname, sched in _schedule_cases(which).items():
        A = sched.n_agents
        configs = {
            "shifts": dict(engine="shifts", apd=1),
            "ppermute": dict(engine="ppermute", apd=1),
            "ppermute_fused": dict(engine="ppermute", apd=1, fused=True),
            "ppermute_fused_b4": dict(engine="ppermute", apd=4, fused=True),
        }
        for cname, c in configs.items():
            apd = c["apd"]
            mesh = axes = None
            if c["engine"] == "ppermute":
                mesh = make_gossip_mesh(A, agents_per_device=apd)
                axes = gossip_agent_axes(mesh)
            mix = make_schedule_mixer(sched, c["engine"], mesh=mesh,
                                      agent_axes=axes,
                                      use_fused_kernel=c.get("fused", False))
            x = jax.random.normal(jax.random.PRNGKey(0), (A, d))
            if mesh is not None:
                x = jax.device_put(x, NamedSharding(mesh, P(axes)))
            # one jitted application per distinct round (concrete step →
            # no switch), weighted over the `steps`-step window
            us_round = {r: timeit_us(jax.jit(lambda t, r=r: mix(t, step=r)),
                                     x, iters=max(iters // sched.period, 2))
                        for r in range(sched.period)}
            us = sum(us_round[t % sched.period] for t in range(steps)) / steps
            wire = sum(wire_bytes_per_step(sched, t, elems_per_agent=d,
                                           agents_per_device=apd,
                                           engine=c["engine"], codec=codec)
                       for t in range(steps)) / steps
            # pad-waste accounting: the wire ships *logical* payloads (the
            # permutes run on raw leaves), but the fused combine kernel
            # streams each per-device shard padded to whole
            # (BLOCK_ROWS, 128) grid tiles by kernels/ops._pack — the
            # padded column is the combine's true HBM traffic, which the
            # logical model undercounts for any d not tile-aligned.
            from repro.kernels.ops import padded_size
            n_dev = A // apd
            n_streams = sum(len(sched.round(t).terms) + 1
                            for t in range(steps)) / steps
            combine_logical = int(n_streams * A * d * 4)
            combine_padded = (int(n_streams * n_dev
                                  * padded_size(apd * d, BLOCK_ROWS) * 4)
                              if c.get("fused") else combine_logical)
            results.append({
                "schedule": sname, "config": cname, "engine": c["engine"],
                "agents": A, "agents_per_device": apd, "d": d,
                "period": sched.period, "steps": steps,
                "block_rows": BLOCK_ROWS,
                "us_per_step": round(us, 1),
                "wire_format": wire_fmt,
                "wire_bytes_per_step": int(wire),
                "compression_ratio": round(codec.compression_ratio(d), 3),
                "combine_hbm_bytes_per_step": combine_logical,
                "combine_hbm_bytes_padded_per_step": combine_padded,
                "permutes_per_step": max(
                    sum(1 for t in rnd.terms if t.shift != 0)
                    for rnd in sched.rounds),
            })
    return results


# ---------------------------------------------------------------------------
# end-to-end EDM step: leaf-wise vs bus-resident (DESIGN §5)
# ---------------------------------------------------------------------------

# model size per benchmarked config (dense family): depth scales the
# parameter set at fixed width, isolating the per-leaf launch/permute
# overhead the bus amortizes from width-bound grad compute.  This repo's
# models stack layers into scanned leaves, so the leaf count stays
# moderate (L=12) and the measured delta is a LOWER bound on what an
# unstacked ~100-leaf tree gains from the bus.
E2E_SIZES = {
    "small": dict(n_layers=2, d_model=64, d_ff=128),
    "medium": dict(n_layers=6, d_model=64, d_ff=128),
    "large": dict(n_layers=12, d_model=64, d_ff=128),
}


def e2e_step_sweep(iters: int = 6) -> List[dict]:
    """Leaf-wise vs bus-resident **full train step** (fwd + bwd + EDM update
    + gossip; ppermute engine, n=8 ring) across model sizes.

    Wall-clock times the integrated jitted ``build_train_step`` of each
    path (the per-step ``unpack``/``pack`` the bus pays for loss/grad is
    inside the timed region; the grad computation is identical in both, so
    the delta is the update+gossip machinery).  The unfused update chains
    are timed — interpret-mode Pallas is not representative on CPU — while
    the modeled columns carry what matters on TPU: permutes/step, kernel
    launches/step, and fused-path HBM bytes **padded** (what the kernels
    actually stream after ``_pack`` pad-to-grid) vs **logical** (data
    bytes).  The bus pays one tail pad for the whole tree; the leaf-wise
    path pads every leaf to a whole (BLOCK_ROWS, 128) tile.

    Also runs the numerical equivalence gates (bus == leaf-wise losses on
    every size, fused == unfused on the bus) — any divergence raises,
    which is the CI contract.

    Needs 8 host devices (use the ``--e2e-step`` outer flag for the
    subprocess wrapper).
    """
    import time

    import numpy as np

    from repro.configs.base import ModelConfig, RunConfig
    from repro.core import (bus as parambus, make_edm_bus,
                            make_schedule_mixer, ring)
    from .common import timeit_us
    from repro.data import SyntheticLM
    from repro.kernels.edm_update import BLOCK_ROWS
    from repro.kernels.ops import padded_size
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from repro.models import build_model
    from repro.train import (build_train_step, bus_layout_for, init_state,
                             make_gossip_schedule)

    A = 8
    topo = ring(A)
    mesh = make_gossip_mesh(A)
    axes = gossip_agent_axes(mesh)
    n_terms = len(topo.terms)
    n_perm = sum(1 for t in topo.terms if t.shift != 0)

    results = []
    overlap_rows = []
    for size, dims in E2E_SIZES.items():
        cfg = ModelConfig(name=f"bus-e2e-{size}", family="dense",
                          n_heads=2, n_kv_heads=2, vocab_size=256,
                          dtype="float32", **dims)
        model = build_model(cfg)
        batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                            n_agents=A).sample(jax.random.PRNGKey(1), 1)
        layout = bus_layout_for(model, A)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        leaf_elems = [int(np.prod(s.shape)) for s in jax.tree.leaves(shapes)]
        L = len(leaf_elems)
        n_logical = sum(leaf_elems)

        us = {}
        losses = {}
        for mode in ("leafwise", "bus", "bus_delayed"):
            packed = mode != "leafwise"
            run = RunConfig(global_batch=A, seq_len=16, algorithm="edm",
                            alpha=0.2, gossip_engine="ppermute",
                            packed_bus=packed,
                            overlap="delayed" if mode == "bus_delayed"
                            else "off", remat=False)
            sched = make_gossip_schedule(run, A)
            state = init_state(model, run, A, jax.random.PRNGKey(0))
            step = jax.jit(build_train_step(model, run, sched, mesh=mesh,
                                            agent_axes=axes),
                           donate_argnums=(0,) if packed else ())
            state, m = step(state, batch)  # compile
            traj = [float(m["loss"])]
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(iters):
                state, m = step(state, batch)
            jax.block_until_ready(m["loss"])
            us[mode] = (time.perf_counter() - t0) / iters * 1e6
            traj.append(float(m["loss"]))
            losses[mode] = traj
        # equivalence gate: identical data + init ⇒ identical losses up to
        # f32 reassociation drift over the iters-step trajectory (the two
        # paths reduce in different orders; tests/test_bus.py pins 3 steps
        # at 1e-5 — a real divergence, e.g. the naive-bf16 bias, is ~1e-2+)
        np.testing.assert_allclose(
            losses["bus"], losses["leafwise"], rtol=1e-4, atol=1e-5,
            err_msg=f"bus vs leaf-wise losses diverged at size={size}")

        # overlap divergence gate (DESIGN §6): the delayed pipeline's loss
        # at step t is evaluated at the pre-mix iterate φ(t) — between the
        # synchronous x(t) and x(t+1) — so gate the 8-step trajectory
        # against the synchronous envelope [loss(t+1), loss(t)] ± 5%.
        # Gate runs at a stable α=0.05: one-step staleness at an
        # aggressive LR degrades per-step progress by design (the §E.1/E.2
        # floor gates below cover the convergence claim); the envelope
        # checks the *semantics* — φ(t) must sit between x(t) and x(t+1).
        traj_sync = _e2e_loss_traj(model, batch, mesh, axes, A, "off",
                                   steps=9)
        traj_del = _e2e_loss_traj(model, batch, mesh, axes, A, "delayed")
        assert abs(traj_del[0] - traj_sync[0]) < 1e-5, \
            (size, "overlap step 0 must match the synchronous step exactly")
        for t in range(len(traj_sync) - 1):
            lo = min(traj_sync[t], traj_sync[t + 1])
            hi = max(traj_sync[t], traj_sync[t + 1])
            tol = 0.05 * abs(traj_sync[t])
            assert lo - tol <= traj_del[t] <= hi + tol, (
                f"overlap divergence gate failed at size={size} step={t}: "
                f"delayed={traj_del[t]:.5f} outside sync envelope "
                f"[{lo:.5f}, {hi:.5f}] ± {tol:.5f}")

        # gossip-only wall time of the synchronous path on this size's bus
        # (the wire+combine the delayed pipeline moves off the critical
        # path); pct_gossip_hidden = how much of it the overlap recovered.
        run_g = RunConfig(global_batch=A, seq_len=16, algorithm="edm",
                          alpha=0.2, gossip_engine="ppermute",
                          packed_bus=True, remat=False)
        sched_g = make_gossip_schedule(run_g, A)
        mix_g = make_schedule_mixer(sched_g, "ppermute", mesh=mesh,
                                    agent_axes=axes)
        bus0 = init_state(model, run_g, A, jax.random.PRNGKey(0))["params"]
        gossip_us = timeit_us(jax.jit(lambda b: mix_g(b, step=0)), bus0,
                              iters=max(iters * 3, 10))
        hidden = (us["bus"] - us["bus_delayed"]) / max(gossip_us, 1e-9)
        overlap_rows.append({
            "size": size, "agents": A, "elems_per_agent": n_logical,
            "block_rows": layout.block_rows,
            "us_per_step_off": round(us["bus"], 1),
            "us_per_step_delayed": round(us["bus_delayed"], 1),
            "speedup_off_to_delayed":
                round(us["bus"] / us["bus_delayed"], 3),
            "gossip_us_per_step": round(gossip_us, 1),
            # share of the synchronous step the wire occupies on THIS
            # backend — the ceiling of what overlap can recover here; on
            # the CPU host mesh it is single-digit %, so pct_gossip_hidden
            # is dominated by step-time variance (the TPU ICI share is the
            # number that matters, see DESIGN §6).
            "gossip_pct_of_step": round(100.0 * gossip_us / us["bus"], 1),
            "pct_gossip_hidden": round(100.0 * hidden, 1),
            "divergence_gate": "pass",
        })

        # fused-path HBM model (f32): the EDM update streams 7 buffers of
        # the full per-agent set, the n-ary combine n_terms + 1 — padded to
        # _pack's grid tiles per launch (per leaf, or once for the bus).
        streams = 7 + n_terms + 1
        hbm_logical = streams * A * n_logical * 4
        leaf_padded = (7 * sum(padded_size(A * n, BLOCK_ROWS)
                               for n in leaf_elems)
                       + (n_terms + 1) * A * sum(padded_size(n, BLOCK_ROWS)
                                                 for n in leaf_elems)) * 4
        bus_padded = streams * A * layout.padded_elems * 4
        # wire bytes derive from the run's wire codec (DESIGN §9) — this
        # sweep ships the f32 bus (identity codec), so payload_bytes is
        # 4 B/elem here; the quantized formats are swept by wire_sweep
        from repro.core.wire import make_codec
        wire_pb = make_codec("f32", layout.block_rows).payload_bytes
        common = {"size": size, "n_leaves": L, "agents": A,
                  "elems_per_agent": n_logical,
                  "block_rows": layout.block_rows,
                  "wire_format": "f32",
                  "wire_bytes_logical": n_perm * A * wire_pb(n_logical)}
        results.append({**common, "path": "leafwise",
                        "us_per_step": round(us["leafwise"], 1),
                        "permutes_per_step": L * n_perm,
                        "kernel_launches_per_step": 2 * L,
                        "hbm_bytes_logical": hbm_logical,
                        "hbm_bytes_padded": leaf_padded,
                        "wire_bytes_padded": n_perm * A * wire_pb(n_logical)})
        results.append({**common, "path": "bus",
                        "us_per_step": round(us["bus"], 1),
                        "permutes_per_step": n_perm,
                        "kernel_launches_per_step": 2,
                        "hbm_bytes_logical": hbm_logical,
                        "hbm_bytes_padded": bus_padded,
                        "wire_bytes_padded":
                            n_perm * A * wire_pb(layout.padded_elems),
                        "speedup_vs_leafwise":
                            round(us["leafwise"] / us["bus"], 2)})

        # gate 2 (smallest size only): fused bus kernel == unfused bus at
        # the optimizer level.
        if size == "small":
            import jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.core import make_mixer
            mix = make_mixer(topo, "ppermute", mesh=mesh, agent_axes=axes)
            params1 = model.init(jax.random.PRNGKey(0))
            params = jax.device_put(
                jax.tree.map(
                    lambda l: jnp.broadcast_to(l[None], (A,) + l.shape),
                    params1),
                NamedSharding(mesh, P("data")))
            xb = parambus.pack_tree(layout, params)
            gb = parambus.pack_tree(
                layout, jax.tree.map(lambda x: 0.1 * x, params))
            o_un = make_edm_bus(0.05, 0.9, mix,
                                block_rows=layout.block_rows)
            o_fu = make_edm_bus(0.05, 0.9, mix,
                                block_rows=layout.block_rows,
                                use_fused_kernel=True)
            stb = o_un.init(xb)
            x_un, _ = o_un.step(xb, gb, stb)
            x_fu, _ = o_fu.step(xb, gb, stb)
            np.testing.assert_allclose(
                np.asarray(x_fu), np.asarray(x_un), rtol=1e-5, atol=1e-5,
                err_msg="fused bus kernel vs unfused bus diverged")
    return {"rows": results, "overlap": overlap_rows}


def _e2e_loss_traj(model, batch, mesh, axes, A, overlap, steps: int = 8):
    """Fresh-state loss trajectory of the packed-bus train step with the
    given overlap mode at a stable α — the divergence-gate input."""
    from repro.configs.base import RunConfig
    from repro.train import build_train_step, init_state, make_gossip_schedule

    run = RunConfig(global_batch=A, seq_len=16, algorithm="edm", alpha=0.05,
                    gossip_engine="ppermute", packed_bus=True,
                    overlap=overlap, remat=False)
    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, sched, mesh=mesh,
                                    agent_axes=axes))
    traj = []
    for _ in range(steps):
        state, m = step(state, batch)
        traj.append(float(m["loss"]))
    return traj


def _bench_subprocess(argv: List[str], marker: str, devices: int,
                      label: str, extra_env: Dict | None = None):
    """Re-exec this module with a forced host-platform device count and
    parse the marker-prefixed JSON line — the one subprocess wrapper
    behind every multi-device sweep (XLA_FLAGS must be set before jax
    initializes, so the sweeps cannot run in-process)."""
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": os.path.join(REPO, "src")
           + (os.pathsep + os.environ["PYTHONPATH"]
              if os.environ.get("PYTHONPATH") else ""),
           **(extra_env or {})}
    r = subprocess.run([sys.executable, "-m", "benchmarks.gossip_micro"]
                       + argv, cwd=REPO, env=env, capture_output=True,
                       text=True, timeout=900)
    for line in r.stdout.splitlines():
        if line.startswith(marker):
            return json.loads(line[len(marker):])
    raise RuntimeError(f"{label} failed:\n{r.stdout[-2000:]}"
                       f"\n{r.stderr[-2000:]}")


def _e2e_subprocess(iters: int = 6) -> dict:
    """Run :func:`e2e_step_sweep` under an 8-device host platform."""
    return _bench_subprocess(["--e2e-inner", "--iters", str(iters)],
                             _E2E_MARKER, 8, "e2e step sweep")


# ---------------------------------------------------------------------------
# shard-resident gossip: sharded vs gathered (DESIGN §7)
# ---------------------------------------------------------------------------

SHARD_ROWS_SIZES = (2048, 8192, 16384)


def sharded_sweep(iters: int = 20) -> List[dict]:
    """Sharded vs gathered gossip on a 2-pod × 4-shard host mesh
    (DESIGN §7): per bus size, us/step and wire bytes/step for

    * ``sharded``  — the bus row-sharded ``P('pod', 'data')``; every
      permute ships each shard's own ``rows/S`` block (shard-local);
    * ``gathered`` — the pre-§7 composition: rows replicated over the
      shard axis (``P('pod', None)``), so every shard ships the FULL
      per-agent payload and the wire carries S× the bytes.

    Includes the equivalence gate (sharded ppermute == dense oracle ==
    shard-resident all-gather oracle — any divergence raises, the CI
    contract for the pod-fsdp path).  Needs 8 host devices (use the
    ``--sharded`` outer flag for the subprocess wrapper).
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import (make_mixer, mix_dense, mix_dense_sharded, ring)
    from repro.launch.mesh import make_gossip_mesh
    from .common import timeit_us

    A, S = 2, 4
    topo = ring(A)
    n_perm = sum(1 for t in topo.terms if t.shift != 0)
    mesh = make_gossip_mesh(A, pods=A, shards=S)
    results = []
    for rows in SHARD_ROWS_SIZES:
        x = jax.random.normal(jax.random.PRNGKey(rows), (A, rows, 128))
        want = np.asarray(mix_dense(topo, x))
        for mode in ("sharded", "gathered"):
            spec = P("pod", "data") if mode == "sharded" else P("pod")
            xs = jax.device_put(x, NamedSharding(mesh, spec))
            for fused in (False, True):
                kw = dict(mesh=mesh, agent_axes="pod",
                          use_fused_kernel=fused)
                if mode == "sharded":
                    kw["shard_axes"] = "data"
                mix = jax.jit(make_mixer(topo, "ppermute", **kw))
                # equivalence gate: both layouts must match the oracle
                np.testing.assert_allclose(
                    np.asarray(mix(xs)), want, rtol=1e-5, atol=1e-6,
                    err_msg=f"sharded-gossip gate: {mode} fused={fused} "
                            f"rows={rows} diverged from the dense oracle")
                if mode == "sharded" and not fused:
                    np.testing.assert_allclose(
                        np.asarray(mix_dense_sharded(topo, mesh, "pod",
                                                     "data", xs)),
                        want, rtol=1e-5, atol=1e-6,
                        err_msg=f"shard-resident oracle gate rows={rows}")
                us = timeit_us(mix, xs, iters=iters)
                rows_wire = rows // S if mode == "sharded" else rows
                # bytes derive from the wire codec (DESIGN §9; f32 here —
                # the quantized × sharded composition is gated by
                # wire_sweep's pod gate)
                from repro.core.wire import make_codec
                wire_pb = make_codec("f32", 8).payload_bytes
                results.append({
                    "mode": mode, "fused": fused, "agents": A, "shards": S,
                    "rows": rows, "elems_per_agent": rows * 128,
                    "us_per_step": round(us, 1),
                    "permutes_per_step": n_perm,
                    "wire_format": "f32",
                    # per-device payload of ONE gossip permute — the number
                    # that drops by the shard factor S (sharded mode keeps
                    # each FSDP shard's own row block on the wire)
                    "wire_bytes_per_device_per_term":
                        wire_pb(rows_wire * 128),
                    # summed over the S shards of every agent
                    "wire_bytes_per_step":
                        n_perm * A * S * wire_pb(rows_wire * 128),
                    "divergence_gate": "pass",
                })
    return results


def write_shard_bench_json(results: List[dict]) -> str:
    """Persist the sharded-vs-gathered sweep to BENCH_shard.json."""
    payload = {
        "bench": "gossip_sharded_vs_gathered",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "note": (
            "Shard-resident gossip (DESIGN §7) on a 2-pod x 4-shard host "
            "mesh: 'sharded' permutes each FSDP shard's own rows/S block "
            "(P('pod','data')); 'gathered' is the pre-composition layout "
            "with rows replicated over the shard axis, so every shard "
            "ships the full per-agent payload — S x the wire bytes and, "
            "with real FSDP state, an all-gather before every permute.  "
            "CPU wall-clock bounds structure only; the "
            "wire_bytes_per_device_per_term column is the modeled TPU "
            "claim, and the divergence gate (sharded == dense oracle) is "
            "the backend-independent contract."),
        "results": results,
    }
    with open(BENCH_SHARD_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_SHARD_JSON


def _shard_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"gossip_shard/rows={row['rows']}/{row['mode']}"
        f"{'_fused' if row['fused'] else ''}",
        row["us_per_step"],
        f"A={row['agents']};S={row['shards']};"
        f"wire_dev_term={row['wire_bytes_per_device_per_term']};"
        f"wire_step={row['wire_bytes_per_step']}") for row in rows]


def _shard_subprocess(iters: int = 20) -> List[dict]:
    """Run :func:`sharded_sweep` under an 8-device host platform."""
    return _bench_subprocess(["--sharded-inner", "--iters", str(iters)],
                             _SHARD_MARKER, 8, "sharded sweep")


# ---------------------------------------------------------------------------
# overlap divergence gates (DESIGN §6) — dense-oracle W, single device
# ---------------------------------------------------------------------------

def _edm_sync_vs_delayed(grad_fn, x0, W, *, alpha: float, beta: float,
                         steps: int, seed: int, eval_fn):
    """Eval trajectories of synchronous EDM vs the delayed (one-step-stale
    mixing) pipeline variant under a dense W, driven by the SAME noise keys
    — the only difference is where the gradient is evaluated: at the mixed
    iterate x(t) = W φ(t) (sync) vs the pre-mix φ(t) (delayed)."""
    import jax.numpy as jnp

    Wj = jnp.asarray(W, jnp.float32)

    def sync_body(carry, key):
        x, m, psi = carry
        g = grad_fn(x, key)
        m2 = beta * m + (1.0 - beta) * g
        psi2 = x - alpha * m2
        phi = psi2 + x - psi
        x2 = Wj @ phi
        return (x2, m2, psi2), eval_fn(x2)

    def delayed_body(carry, key):
        phi, m, psi = carry
        x = Wj @ phi               # complete: the in-flight payload's mix
        g = grad_fn(phi, key)      # compute: grads at the pre-mix iterate
        m2 = beta * m + (1.0 - beta) * g
        psi2 = x - alpha * m2
        phi2 = psi2 + x - psi
        return (phi2, m2, psi2), eval_fn(x)

    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    z = jnp.zeros_like(x0)
    _, e_sync = jax.lax.scan(sync_body, (x0, z, x0), keys)
    _, e_del = jax.lax.scan(delayed_body, (x0, z, x0), keys)
    import numpy as np
    return np.asarray(e_sync), np.asarray(e_del)


def overlap_divergence_gates(verbose: bool = True) -> dict:
    """The §E.1 quadratic and §E.2 logistic gates for ``overlap="delayed"``:
    the stale-mixing variant must converge to (near) the synchronous floor.
    Raises on failure — the CI contract for the overlap pipeline."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ring
    from repro.data import logistic_problem, quadratic_problem

    gates = {}
    n = 32
    W = ring(n).dense_matrix()

    stoch, _, x_opt, zeta2 = quadratic_problem(n, d=10, p=20, c=1.0,
                                               sigma=0.05, seed=0)
    x0 = jnp.zeros((n, 10))
    err = lambda x: jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1))
    e_sync, e_del = _edm_sync_vs_delayed(stoch, x0, W, alpha=0.05, beta=0.9,
                                         steps=1500, seed=0, eval_fn=err)
    floor_s = float(np.mean(e_sync[-150:]))
    floor_d = float(np.mean(e_del[-150:]))
    assert floor_d <= 2.0 * floor_s + 1e-8, \
        f"quadratic overlap gate: delayed floor {floor_d:.3e} vs " \
        f"sync {floor_s:.3e}"
    assert floor_d < float(e_del[0]), "quadratic overlap gate: no progress"
    gates["quadratic"] = {"steps": 1500, "zeta2": zeta2,
                          "floor_sync": floor_s, "floor_delayed": floor_d,
                          "ratio": round(floor_d / max(floor_s, 1e-12), 3)}
    if verbose:
        print(f"  overlap gate quadratic: sync={floor_s:.3e} "
              f"delayed={floor_d:.3e} ratio={gates['quadratic']['ratio']}")

    stoch, _, mean_loss = logistic_problem(n, d=20, m=500, seed=0)
    x0 = jnp.zeros((n, 20))
    lloss = lambda x: mean_loss(jnp.mean(x, axis=0))
    l_sync, l_del = _edm_sync_vs_delayed(stoch, x0, W, alpha=0.1, beta=0.9,
                                         steps=800, seed=1, eval_fn=lloss)
    fin_s = float(np.mean(l_sync[-80:]))
    fin_d = float(np.mean(l_del[-80:]))
    assert fin_d <= 1.05 * fin_s + 1e-8, \
        f"logistic overlap gate: delayed {fin_d:.4f} vs sync {fin_s:.4f}"
    gates["logistic"] = {"steps": 800, "loss_sync": fin_s,
                         "loss_delayed": fin_d,
                         "ratio": round(fin_d / max(fin_s, 1e-12), 4)}
    if verbose:
        print(f"  overlap gate logistic: sync={fin_s:.4f} "
              f"delayed={fin_d:.4f} ratio={gates['logistic']['ratio']}")
    return gates


def write_overlap_bench_json(overlap_rows: List[dict], gates: dict) -> str:
    """Persist the overlap pipeline sweep + divergence gates to
    BENCH_overlap.json at the repo root."""
    payload = {
        "bench": "gossip_overlap_pipeline",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "note": (
            "CPU host-mesh wall clock validates structure and parity only: "
            "XLA CPU executes collectives inline, so the wire cannot hide "
            "behind compute here and gossip is a single-digit % of the "
            "step (gossip_pct_of_step).  The overlap claim is the TPU "
            "half: the delayed step's permute-starts precede the backward "
            "pass and the payload stack is complete()'s only wire "
            "dependency (DESIGN §6); divergence_gates carry the "
            "backend-independent correctness contract."),
        "results": overlap_rows,
        "divergence_gates": gates,
    }
    with open(BENCH_OVERLAP_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_OVERLAP_JSON


def _overlap_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"edm_step/{row['size']}/bus_delayed", row["us_per_step_delayed"],
        f"off={row['us_per_step_off']};"
        f"speedup={row['speedup_off_to_delayed']}x;"
        f"gossip_us={row['gossip_us_per_step']};"
        f"hidden={row['pct_gossip_hidden']}%") for row in rows]


# ---------------------------------------------------------------------------
# elastic fault-tolerant gossip: churn sweep + divergence gates (DESIGN §8)
# ---------------------------------------------------------------------------

ELASTIC_DROP_RATES = (0.0, 0.1, 0.25)


def elastic_sweep(iters: int = 20, d: int = 1 << 16,
                  drops=ELASTIC_DROP_RATES) -> List[dict]:
    """Churn fault-injection sweep (DESIGN §8): us/step and wire bytes/step
    vs. drop rate for the liveness-masked schedules, {static ring,
    round_robin} × {plain, fused} ppermute on 8 agents / 8 devices.

    Per drop rate a deterministic :class:`DropPlan` (epoch length = the
    base period, so masks are period-aligned) wraps the base schedule in an
    :class:`ElasticSchedule`; every schedule built here re-checks
    Assumption 1 per degraded epoch, and every distinct degraded round is
    gated masked-ppermute == dense-oracle before it is timed — any
    divergence raises (the CI contract for the elastic path).  Timing
    follows :func:`schedule_sweep`: one jitted application per distinct
    round, weighted over one full plan cycle.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import (DropPlan, ElasticSchedule, RoundRobinExp,
                            StaticSchedule, make_schedule_mixer, ring,
                            wire_bytes_per_step)
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import timeit_us

    A = 8
    n_epochs = 3
    mesh = make_gossip_mesh(A)
    axes = gossip_agent_axes(mesh)
    results = []
    for sname, make_base in (("static_ring", lambda: StaticSchedule(ring(A))),
                             ("round_robin", lambda: RoundRobinExp(A))):
        for drop in drops:
            base = make_base()
            plan = DropPlan.random(A, drop, seed=7, n_epochs=n_epochs,
                                   epoch_len=base.period)
            sched = ElasticSchedule(base, plan)
            sched.check_assumption1()
            stats = sched.product_spectral_stats()
            window = n_epochs * base.period   # one full plan cycle
            mix_oracle = make_schedule_mixer(sched, "dense")
            for cname, fused in (("ppermute", False),
                                 ("ppermute_fused", True)):
                mix = make_schedule_mixer(sched, "ppermute", mesh=mesh,
                                          agent_axes=axes,
                                          use_fused_kernel=fused)
                x = jax.random.normal(jax.random.PRNGKey(0), (A, d))
                xs = jax.device_put(x, NamedSharding(mesh, P(axes)))
                us_round = {}
                for r in range(sched.period):
                    got = jax.jit(lambda t, r=r: mix(t, step=r))(xs)
                    import numpy as np
                    np.testing.assert_allclose(
                        np.asarray(got), np.asarray(mix_oracle(x, step=r)),
                        rtol=2e-5, atol=1e-5,
                        err_msg=f"elastic gate: {sname} drop={drop} "
                                f"{cname} round {r} != dense oracle")
                    us_round[r] = timeit_us(
                        jax.jit(lambda t, r=r: mix(t, step=r)), xs,
                        iters=max(iters // sched.period, 2))
                us = sum(us_round[int(sched.round_index(t))]
                         for t in range(window)) / window
                wire = sum(wire_bytes_per_step(sched, t, elems_per_agent=d,
                                               engine="ppermute")
                           for t in range(window)) / window
                results.append({
                    "schedule": sname, "config": cname,
                    "drop_rate": drop, "agents": A, "d": d,
                    "base_period": base.period, "epochs": n_epochs,
                    "us_per_step": round(us, 1),
                    "wire_bytes_per_step": int(wire),
                    "permutes_per_step": stats["permutes_per_step"],
                    "lambda_max": round(stats["lambda"], 4),
                    "gap_min": round(stats["gap"], 4),
                })
    return results


def _step_W_table(sched, steps: int):
    """(steps, n, n) float32 per-step dense mixing matrices — the oracle
    for schedules whose W varies with the step (ElasticSchedule)."""
    import numpy as np
    mats, idx = {}, []
    for t in range(steps):
        r = int(sched.round_index(t))
        if r not in mats:
            mats[r] = sched.round(t).dense_matrix()
        idx.append(r)
    return np.stack([mats[r] for r in idx]).astype(np.float32)


def _edm_churn_trajectory(grad_fn, x0, W_steps, *, alpha: float, beta: float,
                          seed: int, eval_fn):
    """Synchronous EDM under a per-step W table (all agents keep computing
    local updates — churn only degrades the mixing, which is exactly what
    the liveness-masked trainer does)."""
    import jax.numpy as jnp
    import numpy as np

    Wj = jnp.asarray(W_steps, jnp.float32)

    def body(carry, inp):
        key, W = inp
        x, m, psi = carry
        g = grad_fn(x, key)
        m2 = beta * m + (1.0 - beta) * g
        psi2 = x - alpha * m2
        phi = psi2 + x - psi
        x2 = W @ phi
        return (x2, m2, psi2), eval_fn(x2)

    keys = jax.random.split(jax.random.PRNGKey(seed), Wj.shape[0])
    z = jnp.zeros_like(x0)
    _, e = jax.lax.scan(body, (x0, z, x0), (keys, Wj))
    return np.asarray(e)


def churn_divergence_gates(verbose: bool = True) -> dict:
    """The §E.1 quadratic and §E.2 logistic gates under a 10 %-drop
    :class:`DropPlan`: the churned run (same noise keys, W degraded per
    epoch) must stay within the neighborhood envelope of the no-churn run,
    evaluated on the always-alive agents (dead agents freeze — correct, but
    not progress).  Raises on failure — the CI contract for ``--churn``."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DropPlan, ElasticSchedule, StaticSchedule, ring
    from repro.data import logistic_problem, quadratic_problem

    gates = {}
    n = 32
    base = StaticSchedule(ring(n))

    # --- §E.1 quadratic: consensus floor within envelope -------------------
    steps = 1500
    plan = DropPlan.random(n, 0.10, seed=3, n_epochs=6, epoch_len=250)
    sched = ElasticSchedule(base, plan)
    sched.check_assumption1()
    alive = plan.always_alive()
    aj = jnp.asarray(alive)
    W_churn = _step_W_table(sched, steps)
    W_flat = np.broadcast_to(ring(n).dense_matrix().astype(np.float32),
                             (steps, n, n))
    stoch, _, x_opt, zeta2 = quadratic_problem(n, d=10, p=20, c=1.0,
                                               sigma=0.05, seed=0)
    x0 = jnp.zeros((n, 10))
    err = lambda x: jnp.mean(jnp.sum((x[aj] - x_opt[None]) ** 2, -1))
    e_flat = _edm_churn_trajectory(stoch, x0, W_flat, alpha=0.05, beta=0.9,
                                   seed=0, eval_fn=err)
    e_churn = _edm_churn_trajectory(stoch, x0, W_churn, alpha=0.05, beta=0.9,
                                    seed=0, eval_fn=err)
    floor_f = float(np.mean(e_flat[-150:]))
    floor_c = float(np.mean(e_churn[-150:]))
    assert floor_c <= 3.0 * floor_f + 1e-8, \
        f"quadratic churn gate: churned floor {floor_c:.3e} vs " \
        f"no-churn {floor_f:.3e}"
    assert floor_c < float(e_churn[0]), "quadratic churn gate: no progress"
    gates["quadratic"] = {
        "steps": steps, "zeta2": zeta2, "drop_rate": 0.10,
        "always_alive": int(len(alive)),
        "floor_nochurn": floor_f, "floor_churn": floor_c,
        "ratio": round(floor_c / max(floor_f, 1e-12), 3)}
    if verbose:
        print(f"  churn gate quadratic: nochurn={floor_f:.3e} "
              f"churn={floor_c:.3e} ratio={gates['quadratic']['ratio']}")

    # --- §E.2 logistic: mean-iterate loss within envelope -------------------
    steps = 800
    plan = DropPlan.random(n, 0.10, seed=5, n_epochs=5, epoch_len=160)
    sched = ElasticSchedule(base, plan)
    sched.check_assumption1()
    alive = plan.always_alive()
    aj = jnp.asarray(alive)
    W_churn = _step_W_table(sched, steps)
    W_flat = np.broadcast_to(ring(n).dense_matrix().astype(np.float32),
                             (steps, n, n))
    stoch, _, mean_loss = logistic_problem(n, d=20, m=500, seed=0)
    x0 = jnp.zeros((n, 20))
    lloss = lambda x: mean_loss(jnp.mean(x[aj], axis=0))
    l_flat = _edm_churn_trajectory(stoch, x0, W_flat, alpha=0.1, beta=0.9,
                                   seed=1, eval_fn=lloss)
    l_churn = _edm_churn_trajectory(stoch, x0, W_churn, alpha=0.1, beta=0.9,
                                    seed=1, eval_fn=lloss)
    fin_f = float(np.mean(l_flat[-80:]))
    fin_c = float(np.mean(l_churn[-80:]))
    assert fin_c <= 1.10 * fin_f + 1e-8, \
        f"logistic churn gate: churned {fin_c:.4f} vs no-churn {fin_f:.4f}"
    gates["logistic"] = {
        "steps": steps, "drop_rate": 0.10, "always_alive": int(len(alive)),
        "loss_nochurn": fin_f, "loss_churn": fin_c,
        "ratio": round(fin_c / max(fin_f, 1e-12), 4)}
    if verbose:
        print(f"  churn gate logistic: nochurn={fin_f:.4f} "
              f"churn={fin_c:.4f} ratio={gates['logistic']['ratio']}")
    return gates


def write_elastic_bench_json(rows: List[dict], gates: dict) -> str:
    """Persist the churn sweep + divergence gates to BENCH_elastic.json at
    the repo root."""
    payload = {
        "bench": "gossip_elastic_churn",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "note": (
            "Liveness-masked gossip under deterministic churn (DESIGN §8). "
            "Every row's schedule passed the per-epoch Assumption-1 "
            "transfer check (degraded rounds doubly stochastic, positive "
            "diagonal, dead rows/cols identity, survivor period product "
            "contracting) and the masked-ppermute == dense-oracle "
            "equivalence gate before timing.  wire_bytes_per_step drops "
            "with the drop rate because dead agents' rows leave the wire "
            "(one permute per nonzero survivor shift); divergence_gates "
            "carry the backend-independent convergence contract under a "
            "10% drop plan, evaluated on the always-alive agents."),
        "results": rows,
        "divergence_gates": gates,
    }
    with open(BENCH_ELASTIC_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_ELASTIC_JSON


def _elastic_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"gossip_elastic/{row['schedule']}/{row['config']}"
        f"/drop={row['drop_rate']}",
        row["us_per_step"],
        f"A={row['agents']};wire_step={row['wire_bytes_per_step']};"
        f"permutes={row['permutes_per_step']};gap={row['gap_min']}")
        for row in rows]


def _elastic_subprocess(iters: int = 20) -> List[dict]:
    """Run :func:`elastic_sweep` under an 8-device host platform."""
    return _bench_subprocess(["--churn-inner", "--iters", str(iters)],
                             _ELASTIC_MARKER, 8, "elastic churn sweep")


# ---------------------------------------------------------------------------
# quantized gossip wire: codec sweep + EF divergence gates (DESIGN §9)
# ---------------------------------------------------------------------------

WIRE_SWEEP_ROWS = 512   # bus rows/agent in the measured wire sweep


def wire_sweep(iters: int = 6) -> List[dict]:
    """Wire-format × fused sweep on an 8-agent ring (8 host devices):
    us/step, codec-derived wire bytes/step and compression ratio for the
    f32 / bf16 / int8 gossip wire (DESIGN §9), each behind three built-in
    equivalence gates (the CI contract for the wire path):

    * **oracle** — the wire-coded ppermute engine (fused and unfused)
      must equal the dense oracle applied to the quantized payload,
      ``mix_dense(topo, Q(x))`` — permutes commute with decode, so the
      match is exact, not approximate;
    * **masked** — same oracle identity on a liveness-degraded round
      (one dead agent), so quantized payloads compose with the elastic
      masks of DESIGN §8;
    * **sharded** — same identity on a 2-pod × 4-shard ``P('pod','data')``
      bus, so the int8 scale blocks stay shard-local (DESIGN §7 × §9).

    Any divergence raises.  Timing is CPU wall-clock (the int8 fused
    combine runs interpret-mode off-TPU — structure only); the byte
    columns are the modeled TPU wire claim.
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import (StaticSchedule, make_mixer, mix_dense, ring,
                            wire_bytes_per_step)
    from repro.core.elastic import degrade_round
    from repro.core.wire import WIRE_FORMATS, make_codec
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import timeit_us

    A, rows, br = 8, WIRE_SWEEP_ROWS, 8
    elems = rows * 128
    topo = ring(A)
    n_perm = sum(1 for t in topo.terms if t.shift != 0)
    mesh = make_gossip_mesh(A)
    axes = gossip_agent_axes(mesh)
    x = jax.random.normal(jax.random.PRNGKey(0), (A, rows, 128))
    xs = jax.device_put(x, NamedSharding(mesh, P(axes)))
    results = []
    for fmt in WIRE_FORMATS:
        codec = make_codec(fmt, br)
        want = np.asarray(mix_dense(topo, codec.quantize(x)))
        enc = jax.jit(codec.encode)(xs)
        for fused in (False, True):
            mix = jax.jit(make_mixer(topo, "ppermute", mesh=mesh,
                                     agent_axes=axes,
                                     use_fused_kernel=fused, wire=codec))
            np.testing.assert_allclose(
                np.asarray(mix(enc)), want, rtol=1e-5, atol=1e-5,
                err_msg=f"wire gate: {fmt} fused={fused} ppermute "
                        f"!= dense oracle on Q(x)")
            us = timeit_us(mix, enc, iters=iters)
            results.append({
                "wire_format": fmt, "fused": fused, "agents": A,
                "rows": rows, "elems_per_agent": elems, "block_rows": br,
                "us_per_step": round(us, 1),
                "wire_bytes_per_step": int(wire_bytes_per_step(
                    StaticSchedule(topo), 0, elems_per_agent=elems,
                    engine="ppermute", codec=codec)),
                "compression_ratio":
                    round(codec.compression_ratio(elems), 3),
                "permutes_per_step": n_perm,
                "divergence_gate": "pass",
            })

    # masked gate: one dead agent's degraded round, int8 wire, both engines
    alive = [a != 3 for a in range(A)]
    mt = degrade_round(topo, alive)
    codec = make_codec("int8", br)
    want = np.asarray(mix_dense(mt, codec.quantize(x)))
    enc = jax.jit(codec.encode)(xs)
    for fused in (False, True):
        mix = jax.jit(make_mixer(mt, "ppermute", mesh=mesh, agent_axes=axes,
                                 use_fused_kernel=fused, wire=codec))
        np.testing.assert_allclose(
            np.asarray(mix(enc)), want, rtol=1e-5, atol=1e-5,
            err_msg=f"wire masked gate: int8 fused={fused} degraded round "
                    f"!= dense oracle on Q(x)")

    # sharded gate: 2-pod × 4-shard P('pod','data') bus, int8 wire — the
    # scale blocks must stay shard-local (DESIGN §7 × §9)
    Ap, S = 2, 4
    pmesh = make_gossip_mesh(Ap, pods=Ap, shards=S)
    ptopo = ring(Ap)
    xp = jax.random.normal(jax.random.PRNGKey(1), (Ap, rows, 128))
    want = np.asarray(mix_dense(ptopo, codec.quantize(xp)))
    xps = jax.device_put(xp, NamedSharding(pmesh, P("pod", "data")))
    enc = jax.jit(codec.encode)(xps)
    mix = jax.jit(make_mixer(ptopo, "ppermute", mesh=pmesh,
                             agent_axes="pod", shard_axes="data",
                             wire=codec))
    np.testing.assert_allclose(
        np.asarray(mix(enc)), want, rtol=1e-5, atol=1e-5,
        err_msg="wire sharded gate: int8 P('pod','data') != dense oracle")
    return results


def wire_modeled_rows(n: int = 32, rows: int = WIRE_SWEEP_ROWS,
                      block_rows: int = 8) -> List[dict]:
    """Modeled wire bytes/step on the paper's n=32 ring per wire format —
    the acceptance numbers of DESIGN §9 (no devices needed).  Asserts the
    byte-cut floors (bf16 ≥ 2×, int8 ≥ 3.5× vs f32) and that the permute
    count is format-independent (compression changes bytes, not topology).
    """
    from repro.core import StaticSchedule, ring, wire_bytes_per_step
    from repro.core.wire import WIRE_FORMATS, make_codec

    sched = StaticSchedule(ring(n))
    elems = rows * 128
    n_perm = sum(1 for t in sched.round(0).terms if t.shift != 0)
    base = wire_bytes_per_step(sched, 0, elems_per_agent=elems,
                               engine="ppermute")
    out = []
    for fmt in WIRE_FORMATS:
        codec = make_codec(fmt, block_rows)
        b = wire_bytes_per_step(sched, 0, elems_per_agent=elems,
                                engine="ppermute", codec=codec)
        out.append({
            "modeled": True, "agents": n, "rows": rows,
            "elems_per_agent": elems, "block_rows": block_rows,
            "wire_format": fmt, "wire_bytes_per_step": int(b),
            "reduction_vs_f32": round(base / b, 3),
            "compression_ratio":
                round(codec.compression_ratio(elems), 3),
            "permutes_per_step": n_perm,
        })
    by = {r["wire_format"]: r for r in out}
    assert by["bf16"]["reduction_vs_f32"] >= 2.0, by["bf16"]
    assert by["int8"]["reduction_vs_f32"] >= 3.5, by["int8"]
    assert len({r["permutes_per_step"] for r in out}) == 1, out
    return out


def _padded_quantizer(fmt: str):
    """Quantize an ``(n, d)`` iterate through the bus wire codec by padding
    each agent's d-vector into whole ``(8, 128)`` scale blocks — the
    reference wire for the low-dimensional §E problems (the pad tail
    encodes to exact zero, so it never pollutes the scale: the codec's
    absmax sees the real coordinates only when d fills the first rows,
    and zero blocks yield scale 0)."""
    import jax.numpy as jnp

    from repro.core.wire import make_codec

    codec = make_codec(fmt, 8)
    lane, blk = 128, 8 * 128

    def quant(x):
        n, d = x.shape
        rows = 8 * (-(-d // blk))      # whole scale blocks
        buf = jnp.zeros((n, rows * lane), x.dtype).at[:, :d].set(x)
        qd = codec.quantize(buf.reshape(n, rows, lane))
        return qd.reshape(n, rows * lane)[:, :d]
    return quant


def _edm_wire_trajectory(grad_fn, x0, W, *, alpha: float, beta: float,
                         steps: int, seed: int, eval_fn, quant=None,
                         error_feedback: bool = True):
    """Synchronous EDM under a dense W with the gossip payload φ pushed
    through a quantizer — either with the bus-resident error-feedback
    residual (send Q(φ+e), carry e := φ+e − Q(φ+e); DESIGN §9) or naively
    (send Q(φ), no residual — the negative control).  ``quant=None`` is
    the exact f32 wire."""
    import jax.numpy as jnp
    import numpy as np

    Wj = jnp.asarray(W, jnp.float32)

    def body(carry, key):
        x, m, psi, e = carry
        g = grad_fn(x, key)
        m2 = beta * m + (1.0 - beta) * g
        psi2 = x - alpha * m2
        phi = psi2 + x - psi
        if quant is None:
            pay, e2 = phi, e
        elif error_feedback:
            c = phi + e
            pay = quant(c)
            e2 = c - pay
        else:
            pay, e2 = quant(phi), e
        x2 = Wj @ pay
        return (x2, m2, psi2, e2), eval_fn(x2)

    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    z = jnp.zeros_like(x0)
    _, ev = jax.lax.scan(body, (x0, z, x0, z), keys)
    return np.asarray(ev)


def wire_divergence_gates(verbose: bool = True) -> dict:
    """The §E.1 quadratic and §E.2 logistic gates for the quantized wire:
    per format, the error-feedback run must land within 1.05× of the f32
    floor/loss, and the naive-quantization run (same codec, no residual)
    is recorded as the negative control — it must be strictly worse than
    EF on the quadratic floor, or compression would be free and EF dead
    weight.  Raises on failure — the CI contract for ``--wire``."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ring
    from repro.data import logistic_problem, quadratic_problem

    gates = {}
    n = 32
    W = ring(n).dense_matrix()

    # --- §E.1 quadratic: consensus floor within 1.05x of f32 ---------------
    # σ=0.2 (vs the overlap/churn gates' 0.05): EF removes the *bias*
    # amplification — the naive rows' (1−λ)⁻¹ floor blowup — but int8's
    # per-round quantization variance is α- and σ-independent (it scales
    # with absmax(φ) ≈ ‖x‖∞), so the floor-ratio claim is stated in the
    # noise-dominated regime the paper's floor analysis lives in; at
    # σ=0.05 the same EF run sits ≈1.14× of f32 (variance-, not
    # bias-limited) while naive int8 is ~800× — the contrast the
    # negative-control rows pin.
    stoch, _, x_opt, zeta2 = quadratic_problem(n, d=10, p=20, c=1.0,
                                               sigma=0.2, seed=0)
    x0 = jnp.zeros((n, 10))
    err = lambda x: jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1))
    kw = dict(alpha=0.05, beta=0.9, steps=1500, seed=0, eval_fn=err)
    floor = lambda e: float(np.mean(e[-150:]))
    f32_floor = floor(_edm_wire_trajectory(stoch, x0, W, **kw))
    fmts = {}
    for fmt in ("bf16", "int8"):
        q = _padded_quantizer(fmt)
        ef = floor(_edm_wire_trajectory(stoch, x0, W, quant=q, **kw))
        naive = floor(_edm_wire_trajectory(stoch, x0, W, quant=q,
                                           error_feedback=False, **kw))
        assert ef <= 1.05 * f32_floor + 1e-10, \
            f"quadratic wire gate: {fmt}+EF floor {ef:.3e} vs " \
            f"f32 {f32_floor:.3e}"
        assert naive > ef, \
            f"quadratic wire gate: naive {fmt} {naive:.3e} not worse " \
            f"than EF {ef:.3e} — negative control failed"
        fmts[fmt] = {"floor_ef": ef, "floor_naive": naive,
                     "ratio_ef": round(ef / max(f32_floor, 1e-12), 3),
                     "ratio_naive":
                         round(naive / max(f32_floor, 1e-12), 3)}
        if verbose:
            print(f"  wire gate quadratic {fmt}: f32={f32_floor:.3e} "
                  f"ef={ef:.3e} (x{fmts[fmt]['ratio_ef']}) "
                  f"naive={naive:.3e} (x{fmts[fmt]['ratio_naive']})")
    gates["quadratic"] = {"steps": 1500, "zeta2": zeta2,
                          "floor_f32": f32_floor, "formats": fmts}

    # --- §E.2 logistic: mean-iterate loss within 1.05x of f32 --------------
    stoch, _, mean_loss = logistic_problem(n, d=20, m=500, seed=0)
    x0 = jnp.zeros((n, 20))
    lloss = lambda x: mean_loss(jnp.mean(x, axis=0))
    kw = dict(alpha=0.1, beta=0.9, steps=800, seed=1, eval_fn=lloss)
    fin = lambda l: float(np.mean(l[-80:]))
    f32_loss = fin(_edm_wire_trajectory(stoch, x0, W, **kw))
    fmts = {}
    for fmt in ("bf16", "int8"):
        q = _padded_quantizer(fmt)
        ef = fin(_edm_wire_trajectory(stoch, x0, W, quant=q, **kw))
        naive = fin(_edm_wire_trajectory(stoch, x0, W, quant=q,
                                         error_feedback=False, **kw))
        assert ef <= 1.05 * f32_loss + 1e-10, \
            f"logistic wire gate: {fmt}+EF loss {ef:.4f} vs " \
            f"f32 {f32_loss:.4f}"
        fmts[fmt] = {"loss_ef": ef, "loss_naive": naive,
                     "ratio_ef": round(ef / max(f32_loss, 1e-12), 4),
                     "ratio_naive":
                         round(naive / max(f32_loss, 1e-12), 4)}
        if verbose:
            print(f"  wire gate logistic {fmt}: f32={f32_loss:.4f} "
                  f"ef={ef:.4f} (x{fmts[fmt]['ratio_ef']}) "
                  f"naive={naive:.4f} (x{fmts[fmt]['ratio_naive']})")
    gates["logistic"] = {"steps": 800, "loss_f32": f32_loss, "formats": fmts}
    return gates


def write_wire_bench_json(rows: List[dict], modeled: List[dict],
                          gates: dict) -> str:
    """Persist the wire sweep + modeled n=32 bytes + EF divergence gates
    to BENCH_wire.json at the repo root."""
    payload = {
        "bench": "gossip_wire_formats",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "note": (
            "Quantized gossip wire (DESIGN §9): bf16 / int8 per-block-"
            "scaled bus payloads with bus-resident EDM error feedback.  "
            "'results' are measured on an 8-agent host ring behind the "
            "oracle/masked/sharded equivalence gates (CPU wall-clock "
            "bounds structure only — the int8 fused combine runs "
            "interpret-mode off-TPU); 'modeled_n32' carries the paper-"
            "scale byte claim: same permute count per format, bytes cut "
            "2x (bf16) and ~4x (int8 + per-block scales) vs the f32 "
            "wire.  divergence_gates are the backend-independent "
            "convergence contract: EDM with the error-feedback wire "
            "lands within 1.05x of the f32 floor on the §E.1 quadratic "
            "and §E.2 logistic problems, while the naive-quantization "
            "negative-control rows show the persistent-bias floor "
            "inflation EF removes."),
        "results": rows,
        "modeled_n32": modeled,
        "divergence_gates": gates,
    }
    with open(BENCH_WIRE_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_WIRE_JSON


def _wire_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"gossip_wire/{row['wire_format']}"
        f"{'_fused' if row['fused'] else ''}",
        row["us_per_step"],
        f"A={row['agents']};wire_step={row['wire_bytes_per_step']};"
        f"ratio={row['compression_ratio']};"
        f"permutes={row['permutes_per_step']}") for row in rows]


def _wire_subprocess(iters: int = 6) -> List[dict]:
    """Run :func:`wire_sweep` under an 8-device host platform."""
    return _bench_subprocess(["--wire-inner", "--iters", str(iters)],
                             _WIRE_MARKER, 8, "wire sweep")


# ---------------------------------------------------------------------------
# gossip policy groups: per-group cadence / schedule / wire (DESIGN §12)
# ---------------------------------------------------------------------------

GROUPS_SWEEP_WINDOW = 8  # byte-model window; a multiple of every cadence


def groups_sweep(iters: int = 6) -> dict:
    """Policy-group sweep on the smoke MoE transformer (8 host devices,
    DESIGN §12): per ``--gossip-groups`` config, us/step of the group
    mixer on the real grouped bus layout plus the modeled per-group wire
    bytes over a :data:`GROUPS_SWEEP_WINDOW`-step window, behind two
    built-in gates (the CI contract of the ``moe-gossip-smoke`` job):

    * **segment composition** — on the 2-group all-gossip layout
      (``moe:1``) the group mixer must equal the whole-bus schedule mixer
      bit-exactly (ring mixing is row-independent, so slicing the bus into
      contiguous group segments and mixing each cannot change a bit), and
      on the opt-out layout (``moe``) the expert rows must come back
      untouched while the dense rows match the whole-bus mix of their
      slice;
    * **byte accounting** — the opt-out config ships strictly fewer wire
      bytes per window than the ungrouped all-gossip baseline, and on the
      shared 2-group layout the all-gossip − opt-out delta equals the
      experts group's modeled bytes EXACTLY (the group byte model of
      ``repro.core.schedule.group_wire_bytes_per_step``); the slow-cycle
      config (``moe:4``) lands in between, shipping expert bytes on 1-in-4
      steps only.

    Timing is CPU wall-clock (structure only); the byte columns are the
    modeled TPU wire claim.  Any gate failure raises.
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.configs.base import RunConfig
    from repro.core import group_wire_bytes_per_step, make_group_mixer
    from repro.core.mixing import make_schedule_mixer
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from repro.models import build_model
    from repro.train import (bus_layout_for, make_gossip_schedule,
                             make_group_plans, resolve_features)
    from .common import timeit_us

    A, W = 8, GROUPS_SWEEP_WINDOW
    cfg = get_smoke_config("deepseek_moe_16b")
    model = build_model(cfg)
    mesh = make_gossip_mesh(A)
    axes = gossip_agent_axes(mesh)

    configs = [
        ("baseline_all_gossip", ""),       # one dense group, legacy path
        ("grouped_all_gossip", "moe:1"),   # 2-group layout, both every step
        ("moe_opt_out", "moe"),            # experts never gossip
        ("moe_slow_cycle", "moe:4"),       # experts gossip 1-in-4 steps
    ]
    rows_out, by_label = [], {}
    for label, gspec in configs:
        run = RunConfig(global_batch=A, seq_len=8, algorithm="edm",
                        gossip_engine="ppermute", gossip_groups=gspec,
                        remat=False)
        feats = resolve_features(run)
        layout = bus_layout_for(model, A, groups=feats.groups)
        sched = make_gossip_schedule(run, A)
        plans = make_group_plans(run, layout, sched)
        scheds = {p.group.name: p.sched for p in plans
                  if p.sched is not None}
        per_step = [group_wire_bytes_per_step(layout.groups, scheds, t)
                    for t in range(W)]
        window = {g.name: sum(s[g.name] for s in per_step)
                  for g in layout.groups}
        window["total"] = sum(s["total"] for s in per_step)

        mix = make_group_mixer(plans, engine="ppermute", mesh=mesh,
                               agent_axes=axes)
        bus = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(0), (A, layout.rows, 128)),
            NamedSharding(mesh, P(axes)))
        # time a gossip step for every group (the max-cadence step W-1) and
        # a skip step (step 0 — for slow-cycle/opt-out the inactive groups'
        # rows are pure slices there)
        mix_on = jax.jit(lambda b: mix(b, W - 1))
        mix_off = jax.jit(lambda b: mix(b, 0))
        us_on = timeit_us(mix_on, bus, iters=iters)
        us_off = timeit_us(mix_off, bus, iters=iters)
        row = {
            "config": label, "gossip_groups": gspec, "agents": A,
            "rows": layout.rows,
            "group_rows": {g.name: g.rows for g in layout.groups},
            "group_gossip_every": {g.name: g.gossip_every
                                   for g in layout.groups},
            "window_steps": W,
            "wire_bytes_window": {k: int(v) for k, v in window.items()},
            "wire_bytes_per_step_avg": round(window["total"] / W, 1),
            "us_per_step_gossip": round(us_on, 1),
            "us_per_step_skip": round(us_off, 1),
        }
        rows_out.append(row)
        by_label[label] = dict(row, layout=layout, sched=sched, mix=mix,
                               bus=bus)

    # --- segment-composition gates (bit-exact, any divergence raises) ---
    ga = by_label["grouped_all_gossip"]
    whole = make_schedule_mixer(ga["sched"], "ppermute", mesh=mesh,
                                agent_axes=axes)
    for t in range(4):
        want = np.asarray(jax.jit(lambda b, t=t: whole(b, t))(ga["bus"]))
        got = np.asarray(jax.jit(lambda b, t=t: ga["mix"](b, t))(ga["bus"]))
        np.testing.assert_array_equal(
            got, want, err_msg=f"groups gate: 2-group all-gossip mixer != "
                               f"whole-bus schedule mixer at step {t}")
    oo = by_label["moe_opt_out"]
    (eg,) = [g for g in oo["layout"].groups if g.name == "experts"]
    got = np.asarray(jax.jit(lambda b: oo["mix"](b, 0))(oo["bus"]))
    src = np.asarray(oo["bus"])
    np.testing.assert_array_equal(
        got[:, eg.row:eg.row + eg.rows], src[:, eg.row:eg.row + eg.rows],
        err_msg="groups gate: opt-out expert rows were touched by gossip")
    want_dense = np.asarray(jax.jit(lambda b: whole(b, 0))(oo["bus"]))
    dense_rows = [slice(g.row, g.row + g.rows) for g in oo["layout"].groups
                  if g.name != "experts"]
    for sl in dense_rows:
        np.testing.assert_array_equal(
            got[:, sl], want_dense[:, sl],
            err_msg="groups gate: opt-out dense rows != whole-bus mix")

    # --- byte-accounting gates ---
    base = by_label["baseline_all_gossip"]["wire_bytes_window"]["total"]
    all2 = ga["wire_bytes_window"]["total"]
    opt = oo["wire_bytes_window"]["total"]
    slow = by_label["moe_slow_cycle"]["wire_bytes_window"]["total"]
    experts = ga["wire_bytes_window"]["experts"]
    assert opt < base, (opt, base)
    assert all2 - opt == experts, (all2, opt, experts)
    assert opt < slow < all2, (opt, slow, all2)
    assert slow - opt == experts // 4, (slow, opt, experts)
    gates = {
        "segment_composition": "pass",
        "opt_out_rows_untouched": "pass",
        "opt_out_lt_baseline": {"opt_out": int(opt), "baseline": int(base),
                                "status": "pass"},
        "delta_eq_expert_bytes": {"all_gossip": int(all2),
                                  "opt_out": int(opt),
                                  "experts_window": int(experts),
                                  "status": "pass"},
        "slow_cycle_between": {"slow": int(slow), "status": "pass"},
    }
    return {"rows": rows_out, "gates": gates}


def write_groups_bench_json(rows: List[dict], gates: dict) -> str:
    """Persist the policy-group sweep + byte/composition gates to
    BENCH_groups.json at the repo root."""
    payload = {
        "bench": "gossip_policy_groups",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "note": (
            "Gossip policy groups (DESIGN §12): per-leaf-group schedules, "
            "cadences and wire formats over one packed superbuffer.  "
            "'results' are measured on the 8-agent smoke MoE transformer "
            "behind the segment-composition gates (2-group all-gossip == "
            "whole-bus mixer bit-exactly; opt-out expert rows untouched); "
            "the byte columns carry the modeled wire claim: expert "
            "opt-out ships strictly fewer bytes than the all-gossip "
            "baseline, with the all-gossip - opt-out delta equal to the "
            "experts group's modeled bytes exactly, and slow-cycle "
            "(moe:4) in between at 1-in-4 expert steps."),
        "results": rows,
        "gates": gates,
    }
    with open(BENCH_GROUPS_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_GROUPS_JSON


def _groups_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"gossip_groups/{row['config']}",
        row["us_per_step_gossip"],
        f"A={row['agents']};rows={row['rows']};"
        f"wire_window={row['wire_bytes_window']['total']};"
        f"avg_step={row['wire_bytes_per_step_avg']}") for row in rows]


def _groups_subprocess(iters: int = 6) -> dict:
    """Run :func:`groups_sweep` under an 8-device host platform."""
    return _bench_subprocess(["--groups-inner", "--iters", str(iters)],
                             _GROUPS_MARKER, 8, "groups sweep")


# ---------------------------------------------------------------------------
# BLOCK_ROWS autotune (ROADMAP "tune BLOCK_ROWS", CPU-measurable half)
# ---------------------------------------------------------------------------

def autotune_block_rows(candidates=(128, 256, 512, 1024),
                        rows_sizes=(1024, 4096, 8192),
                        iters: int = 5, verbose: bool = True) -> List[dict]:
    """Sweep the Pallas grid-tile height for the fused EDM update and the
    3-ary gossip combine over per-agent bus sizes; prints the argmin per
    size.  On CPU the kernels run in interpret mode — the sweep machinery
    and the printed table are the portable half; re-run on a real TPU for
    the production argmin (REPRO_BLOCK_ROWS / --block-rows set it)."""
    import jax.numpy as jnp

    from repro.kernels.edm_update import edm_update_flat, gossip_axpy_flat
    from .common import timeit_us

    interpret = jax.default_backend() != "tpu"
    out = []
    for rows in rows_sizes:
        ks = jax.random.split(jax.random.PRNGKey(rows), 4)
        bufs = [jax.random.normal(k, (rows, 128), jnp.float32) for k in ks]
        row = {"rows": rows, "elems": rows * 128,
               "backend": jax.default_backend(),
               "interpret": interpret, "candidates": list(candidates)}
        for kernel in ("edm_update", "gossip_axpy"):
            us = {}
            for br in candidates:
                if rows % br:
                    continue
                if kernel == "edm_update":
                    fn = jax.jit(lambda a, b, c, d, br=br: edm_update_flat(
                        a, b, c, d, alpha=0.05, beta=0.9, block_rows=br,
                        interpret=interpret))
                    args = bufs
                else:
                    fn = jax.jit(lambda a, b, c, br=br: gossip_axpy_flat(
                        (a, b, c), (0.5, 0.25, 0.25), block_rows=br,
                        interpret=interpret))
                    args = bufs[:3]
                us[br] = timeit_us(fn, *args, iters=iters)
            best = min(us, key=us.get)
            row[kernel] = {"us": {str(k): round(v, 1) for k, v in us.items()},
                           "best": best}
            if verbose:
                table = " ".join(f"{br}:{u:.0f}us" for br, u in us.items())
                print(f"  block_rows/{kernel}/rows={rows}: {table} "
                      f"-> argmin={best}")
        out.append(row)
    return out


def write_edm_bench_json(results: List[dict]) -> str:
    """Persist the e2e EDM step sweep to BENCH_edm_step.json."""
    payload = {
        "bench": "edm_step_leafwise_vs_bus",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(BENCH_EDM_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_EDM_JSON


def _e2e_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    out = []
    for row in rows:
        if row.get("path") == "equivalence":
            continue
        extra = (f";speedup={row['speedup_vs_leafwise']}x"
                 if "speedup_vs_leafwise" in row else "")
        out.append(csv_row(
            f"edm_step/{row['size']}/{row['path']}", row["us_per_step"],
            f"L={row['n_leaves']};permutes={row['permutes_per_step']};"
            f"launches={row['kernel_launches_per_step']};"
            f"hbm_padded={row['hbm_bytes_padded']}{extra}"))
    return out


def _schedule_subprocess(which: str, steps: int,
                         block_rows: int = 0) -> List[dict]:
    """Run :func:`schedule_sweep` under a 32-device host platform."""
    extra = {"REPRO_BLOCK_ROWS": str(block_rows)} if block_rows else None
    return _bench_subprocess(
        ["--schedule-inner", which, "--steps", str(steps),
         "--block-rows", str(block_rows)],
        _SCHED_MARKER, 32, "schedule sweep", extra_env=extra)


def _sched_csv_rows(rows: List[dict]) -> List[str]:
    from .common import csv_row
    return [csv_row(
        f"gossip_sched/{row['schedule']}/{row['config']}",
        row["us_per_step"],
        f"n={row['agents']};B={row['agents_per_device']};"
        f"wire_bytes={row['wire_bytes_per_step']};"
        f"permutes={row['permutes_per_step']}") for row in rows]


def write_bench_json(results: List[dict]) -> str:
    """Persist the schedule sweep to BENCH_gossip.json at the repo root."""
    payload = {
        "bench": "gossip_schedule_sweep",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return BENCH_JSON


def _sweep_subprocess() -> List[str]:
    """Run :func:`sweep` under a 32-device host platform (one per agent)."""
    return _bench_subprocess(["--sweep"], _SWEEP_MARKER, 32, "engine sweep")


def run(verbose: bool = True) -> Dict:
    from repro.core import make_mixer, ring
    from repro.core.optimizers import make_edm
    from .common import csv_row, timeit_us

    results: Dict = {}
    lines = []
    topo = ring(8)
    d = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (8, d))

    mix_dense = jax.jit(make_mixer(topo, "dense"))
    mix_shift = jax.jit(make_mixer(topo, "shifts"))
    us_d = timeit_us(mix_dense, x)
    us_s = timeit_us(mix_shift, x)
    lines.append(csv_row("gossip/dense_W", us_d, f"n=8;d={d}"))
    lines.append(csv_row("gossip/shift_rolls", us_s,
                         f"n=8;d={d};speedup_vs_dense={us_d / us_s:.2f}x"))

    # EDM unfused vs fused-kernel step (interpret-mode Pallas on CPU — the
    # derived column reports the modeled HBM-stream ratio, which is what
    # matters on TPU: unfused ≈ 11 streams vs fused 7).
    params = {"w": x}
    grads = {"w": 0.1 * x}
    o_un = make_edm(0.05, 0.9, make_mixer(topo), use_fused_kernel=False)
    st = o_un.init(params)
    step_un = jax.jit(lambda p, g, s: o_un.step(p, g, s))
    us_un = timeit_us(step_un, params, grads, st)
    lines.append(csv_row("edm_step/unfused_jnp", us_un,
                         "hbm_streams=11(x,g,m,psi->m,psi,phi + mix)"))
    lines.append(csv_row("edm_step/fused_pallas", float("nan"),
                         "hbm_streams=7;modeled_traffic_ratio=0.64;"
                         "validated=interpret_mode"))

    # engine × topology × fused sweep, one device per agent
    try:
        lines.extend(_sweep_subprocess())
    except Exception as e:  # pragma: no cover - environment-dependent
        lines.append(csv_row("gossip/engine_sweep", float("nan"),
                             f"skipped:{type(e).__name__}"))
        if verbose:
            print(f"  [engine sweep skipped: {e}]")

    # engine × schedule sweep (static vs round_robin vs alt_hier) + wire bytes
    try:
        sched_rows = _schedule_subprocess("all", steps=8)
        lines.extend(_sched_csv_rows(sched_rows))
        results["bench_json"] = write_bench_json(sched_rows)
        if verbose:
            print(f"  [schedule sweep -> {results['bench_json']}]")
    except Exception as e:  # pragma: no cover - environment-dependent
        lines.append(csv_row("gossip_sched/sweep", float("nan"),
                             f"skipped:{type(e).__name__}"))
        if verbose:
            print(f"  [schedule sweep skipped: {e}]")

    results["csv"] = lines
    if verbose:
        print("\n".join("  " + l for l in lines))
    return results


def _cli() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="(inner) engine×topology sweep; needs 32 devices")
    ap.add_argument("--schedule-inner", default=None,
                    help="(inner) engine×schedule sweep; needs 32 devices")
    ap.add_argument("--schedule", default=None,
                    choices=["static", "round_robin", "alt_hier", "all"],
                    help="run the engine×schedule sweep (in a 32-device "
                         "subprocess) and write BENCH_gossip.json")
    ap.add_argument("--steps", type=int, default=8,
                    help="steps per schedule config")
    ap.add_argument("--block-rows", type=int, default=0,
                    help="Pallas BLOCK_ROWS override for the fused combine "
                         "(0 = REPRO_BLOCK_ROWS / default)")
    ap.add_argument("--e2e-step", action="store_true",
                    help="leaf-wise vs bus-resident vs overlapped EDM step "
                         "sweep (in an 8-device subprocess) + equivalence "
                         "and overlap divergence gates; writes "
                         "BENCH_edm_step.json and BENCH_overlap.json")
    ap.add_argument("--e2e-inner", action="store_true",
                    help="(inner) e2e step sweep; needs 8 devices")
    ap.add_argument("--iters", type=int, default=6,
                    help="timing iterations per e2e config")
    ap.add_argument("--autotune-block-rows", action="store_true",
                    help="sweep the kernel BLOCK_ROWS tile over "
                         "{128,256,512,1024} per bus size and print the "
                         "argmin (interpret-mode wall clock off-TPU)")
    ap.add_argument("--sharded", action="store_true",
                    help="sharded vs gathered gossip sweep (DESIGN §7; in "
                         "an 8-device 2-pod x 4-shard subprocess) + the "
                         "sharded==dense equivalence gate; writes "
                         "BENCH_shard.json")
    ap.add_argument("--sharded-inner", action="store_true",
                    help="(inner) sharded sweep; needs 8 devices")
    ap.add_argument("--churn", action="store_true",
                    help="elastic churn sweep (DESIGN §8; in an 8-device "
                         "subprocess): us/step + wire bytes vs drop rate "
                         "with the masked==dense equivalence gate, plus "
                         "the churn divergence gates; writes "
                         "BENCH_elastic.json")
    ap.add_argument("--churn-inner", action="store_true",
                    help="(inner) elastic churn sweep; needs 8 devices")
    ap.add_argument("--wire", action="store_true",
                    help="quantized-wire sweep (DESIGN §9; in an 8-device "
                         "subprocess): us/step + codec-derived wire bytes "
                         "and compression ratio per format with the "
                         "oracle/masked/sharded equivalence gates, plus "
                         "the modeled n=32 byte cut and the EF divergence "
                         "gates; writes BENCH_wire.json")
    ap.add_argument("--wire-inner", action="store_true",
                    help="(inner) wire format sweep; needs 8 devices")
    ap.add_argument("--groups", action="store_true",
                    help="gossip policy-group sweep (DESIGN §12; in an "
                         "8-device subprocess): us/step + modeled per-group "
                         "wire bytes on the smoke MoE transformer per "
                         "--gossip-groups config, behind the segment-"
                         "composition and byte-accounting gates; writes "
                         "BENCH_groups.json")
    ap.add_argument("--groups-inner", action="store_true",
                    help="(inner) policy-group sweep; needs 8 devices")
    args = ap.parse_args()

    if args.sweep:
        print(_SWEEP_MARKER + json.dumps(sweep()))
    elif args.groups_inner:
        print(_GROUPS_MARKER + json.dumps(groups_sweep(iters=args.iters)))
    elif args.groups:
        payload = _groups_subprocess(iters=args.iters)
        print("\n".join(_groups_csv_rows(payload["rows"])))
        print(f"wrote {write_groups_bench_json(payload['rows'], payload['gates'])}")
    elif args.wire_inner:
        print(_WIRE_MARKER + json.dumps(wire_sweep(iters=args.iters)))
    elif args.wire:
        rows = _wire_subprocess(iters=args.iters)
        print("\n".join(_wire_csv_rows(rows)))
        modeled = wire_modeled_rows()
        gates = wire_divergence_gates()
        print(f"wrote {write_wire_bench_json(rows, modeled, gates)}")
    elif args.churn_inner:
        print(_ELASTIC_MARKER + json.dumps(elastic_sweep(iters=args.iters)))
    elif args.churn:
        rows = _elastic_subprocess(iters=args.iters)
        print("\n".join(_elastic_csv_rows(rows)))
        gates = churn_divergence_gates()
        print(f"wrote {write_elastic_bench_json(rows, gates)}")
    elif args.sharded_inner:
        print(_SHARD_MARKER + json.dumps(sharded_sweep(iters=args.iters)))
    elif args.sharded:
        rows = _shard_subprocess(iters=args.iters)
        print("\n".join(_shard_csv_rows(rows)))
        print(f"wrote {write_shard_bench_json(rows)}")
    elif args.autotune_block_rows:
        autotune_block_rows()
    elif args.e2e_inner:
        print(_E2E_MARKER + json.dumps(e2e_step_sweep(iters=args.iters)))
    elif args.e2e_step:
        payload = _e2e_subprocess(iters=args.iters)
        rows, overlap_rows = payload["rows"], payload["overlap"]
        print("\n".join(_e2e_csv_rows(rows)))
        print("\n".join(_overlap_csv_rows(overlap_rows)))
        gates = overlap_divergence_gates()
        print(f"wrote {write_edm_bench_json(rows)}")
        print(f"wrote {write_overlap_bench_json(overlap_rows, gates)}")
    elif args.schedule_inner:
        print(_SCHED_MARKER + json.dumps(schedule_sweep(
            args.schedule_inner, steps=args.steps,
            block_rows=args.block_rows)))
    elif args.schedule:
        rows = _schedule_subprocess(args.schedule, steps=args.steps,
                                    block_rows=args.block_rows)
        print("\n".join(_sched_csv_rows(rows)))
        print(f"wrote {write_bench_json(rows)}")
    else:
        print("\n".join(run()["csv"]))


if __name__ == "__main__":
    _cli()
