"""Microbenchmarks of the gossip/optimizer hot path (CPU wall-clock; the
derived column carries the analytically modeled TPU HBM-traffic ratio).

Two parts:

* in-process engine benches on the current device set (dense vs shifts,
  EDM step fused vs unfused);
* an engine × topology × fused sweep (``--sweep``) that needs one device
  per agent — ``run()`` launches it in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=32`` so it works
  regardless of the parent's device count.  This is the acceptance bench
  for the production ppermute path: on the paper's n=32 ring the
  fused-combine ppermute engine must come in at ≤ the shifts engine.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

import jax

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SWEEP_MARKER = "SWEEP_CSV_JSON:"


def _sweep_cases():
    from repro.core import hierarchical, ring
    return [
        ("ring32", ring(32), 1),
        ("hier2x16", hierarchical(2, 16), 2),
        ("hier4x4_ring", hierarchical(4, 4, intra="ring"), 4),
    ]


def sweep(d: int = 1 << 16, iters: int = 20) -> List[str]:
    """Engine × topology × fused sweep; requires >= 32 devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_mixer
    from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
    from .common import csv_row, timeit_us

    lines: List[str] = []
    for name, topo, pods in _sweep_cases():
        A = topo.n_agents
        mesh = make_gossip_mesh(A, pods=pods)
        axes = gossip_agent_axes(mesh)
        x = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(0), (A, d)),
            NamedSharding(mesh, P(axes)))
        engines = {
            "shifts": make_mixer(topo, "shifts"),
            "ppermute": make_mixer(topo, "ppermute", mesh=mesh,
                                   agent_axes=axes),
            "ppermute_fused": make_mixer(topo, "ppermute", mesh=mesh,
                                         agent_axes=axes,
                                         use_fused_kernel=True),
        }
        us_shifts = None
        for ename, mixer in engines.items():
            us = timeit_us(jax.jit(mixer), x, iters=iters)
            if ename == "shifts":
                us_shifts = us
            lines.append(csv_row(
                f"gossip/{name}/{ename}", us,
                f"n={A};d={d};terms={len(topo.terms)};"
                f"speedup_vs_shifts={us_shifts / us:.2f}x"))
    return lines


def _sweep_subprocess() -> List[str]:
    """Run :func:`sweep` under a 32-device host platform (one per agent)."""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=32",
           "PYTHONPATH": os.path.join(REPO, "src")
           + (os.pathsep + os.environ["PYTHONPATH"]
              if os.environ.get("PYTHONPATH") else "")}
    r = subprocess.run([sys.executable, "-m", "benchmarks.gossip_micro",
                        "--sweep"], cwd=REPO, env=env, capture_output=True,
                       text=True, timeout=900)
    for line in r.stdout.splitlines():
        if line.startswith(_SWEEP_MARKER):
            return json.loads(line[len(_SWEEP_MARKER):])
    raise RuntimeError(f"engine sweep failed:\n{r.stdout[-2000:]}"
                       f"\n{r.stderr[-2000:]}")


def run(verbose: bool = True) -> Dict:
    from repro.core import make_mixer, ring
    from repro.core.optimizers import make_edm
    from .common import csv_row, timeit_us

    results: Dict = {}
    lines = []
    topo = ring(8)
    d = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (8, d))

    mix_dense = jax.jit(make_mixer(topo, "dense"))
    mix_shift = jax.jit(make_mixer(topo, "shifts"))
    us_d = timeit_us(mix_dense, x)
    us_s = timeit_us(mix_shift, x)
    lines.append(csv_row("gossip/dense_W", us_d, f"n=8;d={d}"))
    lines.append(csv_row("gossip/shift_rolls", us_s,
                         f"n=8;d={d};speedup_vs_dense={us_d / us_s:.2f}x"))

    # EDM unfused vs fused-kernel step (interpret-mode Pallas on CPU — the
    # derived column reports the modeled HBM-stream ratio, which is what
    # matters on TPU: unfused ≈ 11 streams vs fused 7).
    params = {"w": x}
    grads = {"w": 0.1 * x}
    o_un = make_edm(0.05, 0.9, make_mixer(topo), use_fused_kernel=False)
    st = o_un.init(params)
    step_un = jax.jit(lambda p, g, s: o_un.step(p, g, s))
    us_un = timeit_us(step_un, params, grads, st)
    lines.append(csv_row("edm_step/unfused_jnp", us_un,
                         "hbm_streams=11(x,g,m,psi->m,psi,phi + mix)"))
    lines.append(csv_row("edm_step/fused_pallas", float("nan"),
                         "hbm_streams=7;modeled_traffic_ratio=0.64;"
                         "validated=interpret_mode"))

    # engine × topology × fused sweep, one device per agent
    try:
        lines.extend(_sweep_subprocess())
    except Exception as e:  # pragma: no cover - environment-dependent
        lines.append(csv_row("gossip/engine_sweep", float("nan"),
                             f"skipped:{type(e).__name__}"))
        if verbose:
            print(f"  [engine sweep skipped: {e}]")

    results["csv"] = lines
    if verbose:
        print("\n".join("  " + l for l in lines))
    return results


if __name__ == "__main__":
    if "--sweep" in sys.argv:
        print(_SWEEP_MARKER + json.dumps(sweep()))
    else:
        print("\n".join(run()["csv"]))
