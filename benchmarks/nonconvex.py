"""Paper Figures 3-4 — non-convex objective with Dirichlet-φ label skew.

Adaptation (DESIGN §2): the container is offline, so CIFAR-10/VGG-11 is
replaced by a 2-layer MLP on a synthetic 10-class Gaussian-blob dataset —
the *measured claim* is preserved: at φ=1.0 all momentum methods are
comparable; at φ=0.1 (severe heterogeneity) EDM keeps converging while
DmSGD-style methods degrade.  Metric: global test loss of the averaged model.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.data import dirichlet_partition
from .common import csv_row, run_algorithm

ALGS = ["edm", "ed", "dmsgd", "dsgt_hb", "qg"]
N_AGENTS, D_IN, N_CLS, HID = 16, 32, 10, 64
ALPHA, BETA, STEPS, BATCH = 0.1, 0.9, 400, 16


def _make_data(n_per_cls=400, seed=0):
    rng = np.random.default_rng(seed)
    mus = rng.normal(size=(N_CLS, D_IN)) * 1.0  # overlapping classes
    X = (mus[:, None] + rng.normal(size=(N_CLS, n_per_cls, D_IN))).reshape(-1, D_IN)
    y = np.repeat(np.arange(N_CLS), n_per_cls)
    perm = rng.permutation(len(y))
    return X[perm].astype(np.float32), y[perm]


def _init_mlp(key, n_agents):
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (D_IN, HID)) * (D_IN ** -0.5)
    w2 = jax.random.normal(k2, (HID, N_CLS)) * (HID ** -0.5)
    one = {"w1": w1, "b1": jnp.zeros(HID), "w2": w2, "b2": jnp.zeros(N_CLS)}
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None],
                                                   (n_agents,) + l.shape), one)


def _loss_one(p, X, y):
    h = jax.nn.relu(X @ p["w1"] + p["b1"])
    logits = h @ p["w2"] + p["b2"]
    return jnp.mean(jax.nn.logsumexp(logits, -1)
                    - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])


def run(verbose: bool = True) -> Dict:
    X, y = _make_data()
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    topo = ring(N_AGENTS)
    results: Dict = {}
    for phi, tag in ((1.0, "phi1.0"), (0.1, "phi0.1")):
        parts = dirichlet_partition(y, N_AGENTS, phi, seed=1)
        # pad each agent's index set to a common size for vmap-able sampling
        L = max(len(p) for p in parts)
        idx = np.stack([np.resize(p, L) for p in parts])
        idxj = jnp.asarray(idx)

        def grad_fn(params, key):
            ks = jax.random.split(key, N_AGENTS)

            def one(p, k, agent_idx):
                sel = agent_idx[jax.random.randint(k, (BATCH,), 0, L)]
                return jax.grad(_loss_one)(p, Xj[sel], yj[sel])

            return jax.vmap(one)(params, ks, idxj)

        def test_loss(params):
            mean_p = jax.tree.map(lambda l: jnp.mean(l, 0), params)
            return _loss_one(mean_p, Xj, yj)

        x0 = _init_mlp(jax.random.PRNGKey(7), N_AGENTS)
        for alg in ALGS:
            t0 = time.perf_counter()
            out = run_algorithm(alg, grad_fn, x0, topo, alpha=ALPHA, beta=BETA,
                                steps=STEPS, eval_fn=test_loss)
            wall = time.perf_counter() - t0
            final = float(jnp.mean(out["metric"][-5:]))
            results[(alg, tag)] = final
            if verbose:
                print(f"  nonconvex {alg:8s} {tag} test_loss={final:.4f} "
                      f"({wall:.1f}s)")
    lines = []
    for alg in ALGS:
        lines.append(csv_row(
            f"nonconvex/{alg}", 0.0,
            f"testloss_phi1={results[(alg, 'phi1.0')]:.4f};"
            f"testloss_phi01={results[(alg, 'phi0.1')]:.4f}"))
    results["csv"] = lines
    return results


if __name__ == "__main__":
    print("\n".join(run()["csv"]))
