"""Roofline table generator — reads the dry-run JSONs (experiments/dryrun/)
and emits the §Roofline table: three terms, dominant bottleneck, useful-FLOP
fraction, per (arch × shape × mesh)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

BASE = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(BASE, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        mesh_part = parts[2] if len(parts) > 2 else ""
        rec_tag = mesh_part.split("_", 1)[1] if "_" in mesh_part else ""
        if rec_tag != tag:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':7s} {'ok':3s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
           f"{'bound':10s} {'useful%':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        rf = r.get("roofline", {})
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:7s} "
            f"{'y' if r.get('ok') else 'N':3s} "
            f"{rf.get('t_compute_s', 0):10.3e} {rf.get('t_memory_s', 0):10.3e} "
            f"{rf.get('t_collective_s', 0):10.3e} "
            f"{rf.get('bottleneck', '-'):10s} "
            f"{100 * rf.get('useful_flop_frac', 0):8.1f}")
    return "\n".join(lines)


def run(verbose: bool = True) -> Dict:
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    out = {"n_records": len(recs), "n_ok": len(ok)}
    if verbose:
        print(table(recs))
        print(f"\n{len(ok)}/{len(recs)} combos compiled OK")
    bounds = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        bounds[b] = bounds.get(b, 0) + 1
    out["csv"] = [f"roofline/summary,0.0,ok={len(ok)}/{len(recs)};"
                  + ";".join(f"{k}={v}" for k, v in sorted(bounds.items()))]
    return out


if __name__ == "__main__":
    run()
