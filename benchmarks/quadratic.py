"""Paper Figure 1 — quadratic (linear-regression) loss, ring(32), λ≈0.99.

For each heterogeneity level ζ² the paper shows: DmSGD / Quasi-Global /
DecentLaM stall at an O(ζ²)-neighborhood while EDM / ED-D² / DSGT(-HB)
converge to the σ²-limited floor regardless of ζ².  We measure the final
mean distance-to-optimum per algorithm and its sensitivity to ζ².
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import ring
from repro.data import quadratic_problem
from .common import csv_row, run_algorithm

ALGS = ["edm", "ed", "dsgd", "dmsgd", "dsgt", "dsgt_hb", "decentlam", "qg"]
N, D, PDIM = 32, 10, 20
ALPHA, BETA, STEPS = 0.05, 0.9, 3000
SIGMA = 0.05


def run(verbose: bool = True) -> Dict:
    topo = ring(N)
    rows = []
    results: Dict = {"lambda": topo.lam()}
    for c, tag in ((100.0, "low_het"), (1.0, "high_het")):
        stoch, full, x_opt, zeta2 = quadratic_problem(
            N, d=D, p=PDIM, c=c, sigma=SIGMA, seed=0)
        x0 = jnp.zeros((N, D))

        def err(x, x_opt=x_opt):
            return jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1))

        for alg in ALGS:
            t0 = time.perf_counter()
            out = run_algorithm(alg, stoch, x0, topo, alpha=ALPHA, beta=BETA,
                                steps=STEPS, eval_fn=err)
            wall = time.perf_counter() - t0
            # steady-state floor: mean over the last 10% of evals
            floor = float(jnp.mean(out["metric"][-30:]))
            results[(alg, tag)] = floor
            rows.append((alg, tag, zeta2, floor, wall))
            if verbose:
                print(f"  quadratic {alg:10s} {tag:9s} zeta2={zeta2:9.3f} "
                      f"floor={floor:.3e} ({wall:.1f}s)")
    lines = []
    for alg in ALGS:
        ratio = results[(alg, "high_het")] / max(results[(alg, "low_het")], 1e-12)
        lines.append(csv_row(f"quadratic/{alg}", 0.0,
                             f"floor_lo={results[(alg, 'low_het')]:.3e};"
                             f"floor_hi={results[(alg, 'high_het')]:.3e};"
                             f"het_ratio={ratio:.2f}"))
    results["csv"] = lines
    return results


if __name__ == "__main__":
    r = run()
    print("\n".join(r["csv"]))
