"""Paper Figure 2 — ℓ₂-regularized logistic regression (strongly convex /
PL case), ring(32), full-batch gradients + additive N(0, σ_s²) noise,
heterogeneity via σ_h.  Metric: ‖∇f(x̄)‖² trajectory and steady floor."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import ring
from repro.data import logistic_problem
from .common import csv_row, run_algorithm

ALGS = ["edm", "ed", "dsgd", "dmsgd", "dsgt", "dsgt_hb"]
N, D = 32, 20
ALPHA, BETA, STEPS = 0.5, 0.9, 1500
SIGMA_S = 0.1


def run(verbose: bool = True) -> Dict:
    topo = ring(N)
    results: Dict = {"lambda": topo.lam()}
    for sigma_h, tag in ((0.3, "low_het"), (2.0, "high_het")):
        stoch, full, mean_loss = logistic_problem(
            N, d=D, sigma_h=sigma_h, sigma_s=SIGMA_S, seed=1)

        def grad_norm_at_mean(x):
            xb = jnp.mean(x, 0)
            g = full(jnp.broadcast_to(xb[None], x.shape))
            return jnp.sum(jnp.mean(g, 0) ** 2)

        x0 = jnp.zeros((N, D))
        for alg in ALGS:
            t0 = time.perf_counter()
            out = run_algorithm(alg, stoch, x0, topo, alpha=ALPHA, beta=BETA,
                                steps=STEPS, eval_fn=grad_norm_at_mean)
            wall = time.perf_counter() - t0
            floor = float(jnp.mean(out["metric"][-15:]))
            results[(alg, tag)] = floor
            if verbose:
                print(f"  logistic {alg:10s} {tag:9s} "
                      f"|grad|^2_floor={floor:.3e} ({wall:.1f}s)")
    lines = []
    for alg in ALGS:
        ratio = results[(alg, "high_het")] / max(results[(alg, "low_het")], 1e-12)
        lines.append(csv_row(
            f"logistic/{alg}", 0.0,
            f"gradsq_lo={results[(alg, 'low_het')]:.3e};"
            f"gradsq_hi={results[(alg, 'high_het')]:.3e};het_ratio={ratio:.2f}"))
    results["csv"] = lines
    return results


if __name__ == "__main__":
    print("\n".join(run()["csv"]))
