"""Serving benchmark (DESIGN §10): continuous batching vs fixed-batch.

Drives one open-loop Poisson request trace — heterogeneous prompt lengths
and generation budgets — through both serving paths on identical weights:

* **fixed_batch** — the seed-style batch-synchronous path
  (:func:`repro.serve.scheduler.run_fixed_batch`): chunks of ``max_slots``
  requests, each chunk waits for its last arrival, pads prompts to one
  static length and decodes ``max(max_new)`` steps for everyone;
* **continuous** — the paged-cache engine
  (:class:`repro.serve.scheduler.ContinuousBatchingEngine`): slot-level
  admit/evict per step, one jitted dispatch for the whole slot batch.

Both paths are warmed on a throwaway trace first so compiles don't ride
the wall-clock (the jitted step is shared via the engine's lru cache; the
engine resets its scheduler state and keeps its compiled callables).

Gates (nonzero exit on failure — the CI contract of ``serve-smoke``):

* **divergence** — every request's continuous-engine output must match the
  dense reference :func:`repro.serve.engine.greedy_generate` token-for-
  token (the per-step logits-level agreement is asserted in
  ``tests/test_serve.py``);
* **speedup** — continuous tokens/s ≥ 2× fixed-batch tokens/s under the
  heterogeneous load;
* **chunked prefill** (DESIGN §11) — on a long-prompt-heavy EXACT-length
  Poisson trace (a continuum no per-length compile cache can pre-warm),
  the chunked engine must beat the legacy per-request-prefill engine by
  ≥ 1.5× on BOTH TTFT p99 and per-token p99 at equal-or-better
  throughput, its output must match the dense reference token-for-token,
  and its ``compile_count`` must be EXACTLY 2 across the two
  prompt-length distributions it saw (bucketed warm + exact measure).

Results land in ``BENCH_serve.json`` at the repo root (tokens/s, p50/p99
per-token latency — token #1 is TTFT incl. queue wait, later tokens are
inter-token gaps).

CLI::

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick] \
        [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import greedy_generate
from repro.serve.paged_cache import PagedCacheConfig
from repro.serve.scheduler import (ContinuousBatchingEngine, poisson_load,
                                   run_fixed_batch)

PROMPT_BUCKETS = (16, 32)
# long-tailed generation budgets: the p75+ tail is what head-of-line
# blocking amplifies (every chunk decodes max(max_new) steps)
NEW_TOKEN_BUCKETS = (8, 8, 16, 96)

# chunked-prefill section (DESIGN §11): long-prompt-heavy, drawn as an
# EXACT length continuum over the bucket span — the traffic shape the
# per-request path cannot pre-warm (a compile per distinct length)
CHUNK_PROMPT_BUCKETS = (32, 96)
CHUNK_NEW_TOKEN_BUCKETS = (8, 16, 16, 32)


def _chunked_section(args, model, params):
    """Legacy per-request prefill vs chunked prefill (DESIGN §11) on a
    long-prompt-heavy exact-length Poisson trace.  Both engines run the
    same step math; what differs is prompt scheduling: the legacy engine
    stalls every live decode slot for one full-prompt prefill per
    admission AND pays a jit compile per distinct prompt length, the
    chunked engine folds fixed-shape chunks into the decode dispatch
    (exactly two compiles, asserted).  Returns (results, ratios, gates,
    mismatches)."""
    max_prompt, max_new = max(CHUNK_PROMPT_BUCKETS), max(
        CHUNK_NEW_TOKEN_BUCKETS)
    ctx = args.window or max_prompt + max_new - 1
    pcfg = PagedCacheConfig(
        page_size=args.page_size,
        num_pages=1 + args.max_slots * (-(-ctx // args.page_size)),
        max_slots=args.max_slots, max_context=ctx, window=args.window)
    trace = poisson_load(args.requests, args.rate, vocab=model.cfg.vocab_size,
                         prompt_buckets=CHUNK_PROMPT_BUCKETS,
                         new_token_buckets=CHUNK_NEW_TOKEN_BUCKETS,
                         prompt_dist="exact", seed=args.seed + 1)
    # bucketed warm trace: the best a per-length compile cache can do
    # against a length continuum — warm the endpoints (and the chunked
    # engine's two step compiles)
    warm = poisson_load(4, rate=1e6, vocab=model.cfg.vocab_size,
                        prompt_buckets=CHUNK_PROMPT_BUCKETS,
                        new_token_buckets=CHUNK_NEW_TOKEN_BUCKETS, seed=2)

    legacy = ContinuousBatchingEngine(model, params, pcfg, attn_impl="ref")
    print("warming legacy per-request engine ...", flush=True)
    legacy.run(warm)
    legacy.reset()
    print("running legacy per-request engine (exact lengths) ...", flush=True)
    leg = legacy.run(trace)

    eng = ContinuousBatchingEngine(model, params, pcfg, attn_impl="ref",
                                   prefill_chunk=args.prefill_chunk)
    print("warming chunked engine ...", flush=True)
    eng.run(warm)
    eng.reset()
    print("running chunked engine (exact lengths) ...", flush=True)
    chk = eng.run(trace)
    # the warm (bucketed) and measured (exact) traces are two different
    # prompt-length distributions; the chunked engine compiled exactly
    # twice (mixed + decode-only) across BOTH
    compile_constant = chk["compile_count"] == 2

    print("checking chunked divergence vs dense reference ...", flush=True)
    mismatches = 0
    for r in trace:
        ref = np.asarray(greedy_generate(
            model, params, {"tokens": jnp.asarray(r.tokens)[None]},
            n_steps=r.max_new))[0]
        if not np.array_equal(ref, eng.completed[r.rid]):
            mismatches += 1

    ratios = {
        "ttft_p99": round(leg["ttft_p99_ms"] / chk["ttft_p99_ms"], 2),
        "per_token_p99": round(leg["p99_ms"] / chk["p99_ms"], 2),
        "tokens_per_s": round(chk["tokens_per_s"] / leg["tokens_per_s"], 2),
    }
    gates = {
        "chunked_divergence": "pass" if mismatches == 0 else
                              f"FAIL ({mismatches}/{len(trace)} requests)",
        "chunked_ttft_p99_1p5x": "pass" if ratios["ttft_p99"] >= 1.5 else
                                 f"FAIL ({ratios['ttft_p99']}x < 1.5x)",
        "chunked_per_token_p99_1p5x":
            "pass" if ratios["per_token_p99"] >= 1.5 else
            f"FAIL ({ratios['per_token_p99']}x < 1.5x)",
        "chunked_throughput_1x":
            "pass" if ratios["tokens_per_s"] >= 1.0 else
            f"FAIL ({ratios['tokens_per_s']}x < 1x)",
        "chunked_compile_constant":
            "pass" if compile_constant else
            f"FAIL (compile_count {chk['compile_count']} != 2)",
    }
    results = {"legacy_exact": leg, "chunked_exact": chk}
    return results, ratios, gates


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--max-slots", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunk width for the chunked-prefill section")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true",
                    help="16-request CI smoke")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    if args.quick:
        args.requests, args.max_slots = 16, 8

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg, decode_window=args.window)
    params = model.init(jax.random.PRNGKey(0))

    max_prompt, max_new = max(PROMPT_BUCKETS), max(NEW_TOKEN_BUCKETS)
    ctx = args.window or max_prompt + max_new - 1
    pcfg = PagedCacheConfig(
        page_size=args.page_size,
        num_pages=1 + args.max_slots * (-(-ctx // args.page_size)),
        max_slots=args.max_slots, max_context=ctx, window=args.window)
    trace = poisson_load(args.requests, args.rate, vocab=cfg.vocab_size,
                         prompt_buckets=PROMPT_BUCKETS,
                         new_token_buckets=NEW_TOKEN_BUCKETS,
                         seed=args.seed)

    # warmup trace: every (prompt, max_new) bucket once, immediate arrivals
    warm = poisson_load(len(PROMPT_BUCKETS) * len(set(NEW_TOKEN_BUCKETS)),
                        rate=1e6, vocab=cfg.vocab_size,
                        prompt_buckets=PROMPT_BUCKETS,
                        new_token_buckets=NEW_TOKEN_BUCKETS, seed=1)

    eng = ContinuousBatchingEngine(model, params, pcfg, attn_impl="ref")
    print("warming continuous engine ...", flush=True)
    eng.run(warm)
    eng.reset()
    print("running continuous engine ...", flush=True)
    cont = eng.run(trace)

    print("warming fixed-batch baseline ...", flush=True)
    run_fixed_batch(model, params, warm, batch_size=args.max_slots,
                    prompt_pad=max_prompt)
    print("running fixed-batch baseline ...", flush=True)
    base = run_fixed_batch(model, params, trace, batch_size=args.max_slots,
                           prompt_pad=max_prompt)

    print("checking divergence vs dense reference ...", flush=True)
    mismatches = 0
    for r in trace:
        ref = np.asarray(greedy_generate(
            model, params, {"tokens": jnp.asarray(r.tokens)[None]},
            n_steps=r.max_new))[0]
        if not np.array_equal(ref, eng.completed[r.rid]):
            mismatches += 1
    speedup = cont["tokens_per_s"] / base["tokens_per_s"]
    gates = {
        "divergence": "pass" if mismatches == 0 else
                      f"FAIL ({mismatches}/{len(trace)} requests)",
        "speedup_2x": "pass" if speedup >= 2.0 else
                      f"FAIL ({speedup:.2f}x < 2x)",
    }

    chunk_results, chunk_ratios, chunk_gates = _chunked_section(
        args, model, params)
    gates.update(chunk_gates)

    doc = {
        "bench": "serve_continuous_batching",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "note": "Continuous batching + paged KV cache vs the seed-style "
                "fixed-batch path (DESIGN §10), identical smoke weights, "
                "one open-loop Poisson trace with long-tailed generation "
                "budgets.  tokens_per_s counts requested tokens only; "
                "p50/p99 are per-token latencies (token #1 = TTFT incl. "
                "queue wait).  divergence gate: the engine's greedy "
                "outputs match the dense reference token-for-token "
                "(per-step logits agreement is asserted in tests/"
                "test_serve.py).  The chunked section (DESIGN §11) runs "
                "the legacy per-request-prefill engine and the chunked "
                "engine on one long-prompt-heavy EXACT-length trace: the "
                "legacy path pays a compile per distinct prompt length "
                "plus a full-prompt decode stall per admission; the "
                "chunked path folds fixed-shape chunks into the decode "
                "dispatch and compiles exactly twice.  CPU wall-clock — "
                "ratios carry the claim, not the absolute tok/s.",
        "config": {
            "arch": cfg.name, "requests": args.requests,
            "poisson_rate_per_s": args.rate, "max_slots": args.max_slots,
            "page_size": args.page_size, "window": args.window,
            "prompt_buckets": list(PROMPT_BUCKETS),
            "new_token_buckets": list(NEW_TOKEN_BUCKETS),
            "num_pages": pcfg.num_pages, "seed": args.seed,
        },
        "chunked_config": {
            "prefill_chunk": args.prefill_chunk,
            "prompt_buckets": list(CHUNK_PROMPT_BUCKETS),
            "new_token_buckets": list(CHUNK_NEW_TOKEN_BUCKETS),
            "prompt_dist": "exact", "seed": args.seed + 1,
        },
        "results": {"fixed_batch": base, "continuous": cont,
                    **chunk_results},
        "speedup_tokens_per_s": round(speedup, 2),
        "chunked_vs_legacy": chunk_ratios,
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(json.dumps(doc["results"], indent=2))
    print(f"speedup: {speedup:.2f}x   gates: {gates}")
    print(f"wrote {args.out}")
    return 0 if all(v == "pass" for v in gates.values()) else 1


if __name__ == "__main__":
    sys.exit(main())
