"""Paper Table 1 — empirical convergence-rate structure of EDM.

Two checks, matching the theory:
 1. Spectral-gap scaling: with σ=0 and fixed heterogeneity, EDM's transient
    heterogeneity term decays with T (rate O(α²ζ₀²/(1-λ)²/T)) — so the error
    after a fixed horizon grows when the ring gets sparser, but still → 0;
    DmSGD's *steady-state* error grows like (1-λ)⁻² and does NOT decay.
 2. Momentum invariance: EDM's bound has no (1-β)⁻¹ step-size restriction —
    convergence floor is ~flat across β ∈ {0, 0.5, 0.9} at fixed α.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core import ring
from repro.data import quadratic_problem
from .common import csv_row, run_algorithm


def run(verbose: bool = True) -> Dict:
    results: Dict = {}
    lines = []
    # --- 1. spectral gap sweep (deterministic grads, heterogeneity on) -----
    for n in (8, 16, 32):
        topo = ring(n)
        stoch, full, x_opt, zeta2 = quadratic_problem(n, c=1.0, sigma=0.0,
                                                      seed=2)
        x0 = jnp.zeros((n, x_opt.shape[0]))

        def err(x, x_opt=x_opt):
            return jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1))

        for alg in ("edm", "dmsgd"):
            out = run_algorithm(alg, lambda x, k: full(x), x0, topo,
                                alpha=0.05, beta=0.9, steps=4000, eval_fn=err)
            floor = float(jnp.mean(out["metric"][-10:]))
            results[(alg, n)] = floor
            if verbose:
                print(f"  rate_sweep {alg:6s} ring({n:2d}) 1-λ="
                      f"{topo.spectral_gap():.4f} err_T={floor:.3e}")
        lines.append(csv_row(
            f"rate_sweep/ring{n}", 0.0,
            f"gap={topo.spectral_gap():.5f};edm={results[('edm', n)]:.3e};"
            f"dmsgd={results[('dmsgd', n)]:.3e}"))

    # --- 2. momentum invariance of EDM -------------------------------------
    topo = ring(32)
    stoch, full, x_opt, zeta2 = quadratic_problem(32, c=1.0, sigma=0.05, seed=3)
    x0 = jnp.zeros((32, x_opt.shape[0]))

    def err(x):
        return jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1))

    for beta in (0.0, 0.5, 0.9):
        out = run_algorithm("edm", stoch, x0, topo, alpha=0.05, beta=beta,
                            steps=3000, eval_fn=err)
        floor = float(jnp.mean(out["metric"][-30:]))
        results[("edm_beta", beta)] = floor
        if verbose:
            print(f"  rate_sweep edm beta={beta} floor={floor:.3e}")
        lines.append(csv_row(f"rate_sweep/edm_beta{beta}", 0.0,
                             f"floor={floor:.3e}"))
    results["csv"] = lines
    return results


if __name__ == "__main__":
    print("\n".join(run()["csv"]))
