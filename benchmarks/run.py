"""Benchmark runner — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable progress).
Figure/table mapping:

  quadratic   → paper Fig 1   (quadratic loss, ζ² sweep)
  logistic    → paper Fig 2   (strongly-convex / PL case)
  nonconvex   → paper Figs 3-4 (Dirichlet-φ label skew)
  rate_sweep  → paper Table 1 (rate structure: spectral gap + β invariance)
  gossip      → systems microbench (mixing engines, fused kernel)
  roofline    → §Roofline summary from the dry-run artifacts
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: quadratic,logistic,"
                         "nonconvex,rate_sweep,gossip,roofline")
    args = ap.parse_args()

    from . import ablations, gossip_micro, logistic, nonconvex, quadratic
    from . import rate_sweep, roofline

    suites = {
        "quadratic": quadratic.run,
        "logistic": logistic.run,
        "nonconvex": nonconvex.run,
        "rate_sweep": rate_sweep.run,
        "ablations": ablations.run,
        "gossip": gossip_micro.run,
        "roofline": roofline.run,
    }
    selected = (args.only.split(",") if args.only else list(suites))

    all_csv = ["name,us_per_call,derived"]
    for name in selected:
        print(f"== {name} ==", flush=True)
        res = suites[name](verbose=True)
        all_csv.extend(res.get("csv", []))

    print("\n".join(all_csv))


if __name__ == "__main__":
    main()
