"""Beyond-paper §Perf levers — convergence-side ablations.

Each systems lever that changes the *algorithm* (not just the schedule of the
same math) is measured on the paper's quadratic benchmark so its step-time
win can be weighed against its convergence cost:

  gossip_every k  — local-EDM: gossip every k steps (t_coll ÷ k)
  gossip_dtype    — bf16 gossip payloads (DCI bytes ÷ 2)
  topology        — flat ring (paper) vs bandwidth-aware hierarchical W
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import hierarchical, make_mixer, make_optimizer, ring
from repro.data import quadratic_problem
from .common import csv_row

N, STEPS, ALPHA, BETA = 32, 3000, 0.05, 0.9


def _run_floor(topo, gossip_every=1, gossip_dtype=None, seed=0,
               algorithm="edm"):
    stoch, full, x_opt, zeta2 = quadratic_problem(N, c=1.0, sigma=0.05,
                                                  seed=seed)
    mix = make_mixer(topo)
    if gossip_dtype:
        dt = jnp.dtype(gossip_dtype)
        base_mix = mix
        mix = lambda t: jax.tree.map(
            lambda o, x: o.astype(x.dtype),
            base_mix(jax.tree.map(lambda x: x.astype(dt), t)), t)
    identity = lambda t: t
    opt_g = make_optimizer(algorithm, alpha=ALPHA, beta=BETA, mix=mix)
    opt_l = make_optimizer(algorithm, alpha=ALPHA, beta=BETA, mix=identity)

    x = jnp.zeros((N, x_opt.shape[0]))
    state = opt_g.init(x)

    @jax.jit
    def body(carry, inp):
        x, st = carry
        key, t = inp
        g = stoch(x, key)
        xg, stg = opt_g.step(x, g, st)
        xl, stl = opt_l.step(x, g, st)
        do = (t % gossip_every) == (gossip_every - 1)
        x = jax.tree.map(lambda a, b: jnp.where(do, a, b), xg, xl)
        st = jax.tree.map(lambda a, b: jnp.where(do, a, b), stg, stl)
        err = jnp.mean(jnp.sum((x - x_opt[None]) ** 2, -1))
        return (x, st), err

    keys = jax.random.split(jax.random.PRNGKey(seed), STEPS)
    (_, _), errs = jax.lax.scan(body, (x, state),
                                (keys, jnp.arange(STEPS)))
    return float(jnp.mean(errs[-300:])), float(errs[99])


def run(verbose: bool = True) -> Dict:
    lines = []
    flat = ring(N)
    base_floor, base_e100 = _run_floor(flat)
    lines.append(csv_row("ablation/baseline_ring", 0.0,
                         f"floor={base_floor:.3e};err100={base_e100:.3e}"))
    for k in (2, 4, 8):
        floor, e100 = _run_floor(flat, gossip_every=k)
        lines.append(csv_row(
            f"ablation/gossip_every{k}", 0.0,
            f"floor={floor:.3e};err100={e100:.3e};"
            f"floor_vs_base={floor / base_floor:.2f}x;coll_bytes=1/{k}"))
        if verbose:
            print(f"  gossip_every={k}: floor {floor:.3e} "
                  f"({floor / base_floor:.2f}x base), comm 1/{k}")
    floor, e100 = _run_floor(flat, gossip_dtype="bfloat16")
    lines.append(csv_row("ablation/gossip_bf16", 0.0,
                         f"floor={floor:.3e};floor_vs_base="
                         f"{floor / base_floor:.2f}x;coll_bytes=0.5"))
    if verbose:
        print(f"  bf16 gossip: floor {floor:.3e} ({floor / base_floor:.2f}x)")
    floor, e100 = _run_floor(flat, algorithm="edm_ef")
    lines.append(csv_row("ablation/gossip_bf16_error_feedback", 0.0,
                         f"floor={floor:.3e};floor_vs_base="
                         f"{floor / base_floor:.2f}x;coll_bytes=0.5"))
    if verbose:
        print(f"  bf16+EF gossip (edm_ef): floor {floor:.3e} "
              f"({floor / base_floor:.2f}x) — compression made safe")
    hier = hierarchical(2, 16)
    floor, e100 = _run_floor(hier)
    lines.append(csv_row("ablation/hier_topology", 0.0,
                         f"floor={floor:.3e};err100={e100:.3e};"
                         f"lambda={hier.lam():.4f}"))
    if verbose:
        print(f"  hier(2x16): floor {floor:.3e}, err@100 {e100:.3e} "
              f"(vs base {base_e100:.3e})")
    return {"csv": lines}


if __name__ == "__main__":
    print("\n".join(run()["csv"]))
