"""Gossip policy groups (DESIGN §12): per-leaf-group schedules, cadences,
wire formats and masks over one packed superbuffer.

* layout: preset group assignment over MoE / Mamba pytree paths, per-group
  block alignment, contiguous tiling of the bus, grouped pack/unpack
  round trip, cache identity, and the default-config bit-identity pin
  (``gossip_groups=""`` builds the exact PR-9 layout object);
* feature matrix: ``resolve_features`` / ``resolve_group_specs`` accept
  the presets and the JSON form, reject incompatible compositions with
  AssertionError, and the deprecated ``use_*`` wrappers delegate with a
  DeprecationWarning;
* satellite property test: per-group ``gossip_every`` × schedule period —
  every group's round clock (``gossip_round_step``) visits EVERY round of
  its schedule, including the gcd-hazard pairs that would alias a raw
  step index;
* per-group Assumption 1 via ``make_group_plans`` (schedule overrides
  resolve per group; opt-out groups carry no schedule);
* the per-group wire-byte model (opt-out ships zero; slow-cycle ships on
  1-in-k steps on its own round clock);
* cross-layout checkpoints: a state saved under the 1-group layout
  restores bit-exactly under a 2-group layout and vice versa;
* subprocess pins (8-device host platform): the default config's
  trajectory is bit-identical to an explicit trivial single-group spec
  AND the 2-group all-gossip layout (``assert_array_equal`` on unpacked
  leaves); an opt-out group contributes ZERO extra collective-permutes to
  the lowered HLO.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bus
from repro.core.bus import GroupSpec

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


def _moe_like_tree(A, key=0):
    """Small tree whose paths look like the transformer MoE block —
    ``moe|w_gate`` etc. must land in the experts group, ``moe|shared|*``
    and everything else in dense."""
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    return {
        "embed": jax.random.normal(ks[0], (A, 37, 9)),
        "moe": {
            "router": jax.random.normal(ks[1], (A, 9, 4)),
            "w_gate": jax.random.normal(ks[2], (A, 4, 9, 16)),
            "w_up": jax.random.normal(ks[3], (A, 4, 9, 16)),
            "w_down": jax.random.normal(ks[4], (A, 4, 16, 9)),
            "shared": {"w_gate": jax.random.normal(ks[5], (A, 9, 16))},
        },
    }


# ---------------------------------------------------------------------------
# layout: assignment, alignment, tiling, round trip, default bit-identity
# ---------------------------------------------------------------------------

def test_preset_group_assignment_moe():
    from repro.models.moe import EXPERT_LEAF_PATTERNS, expert_group_spec

    tree = _moe_like_tree(2)
    layout = bus.make_layout(tree, block_rows=8,
                             groups=(expert_group_spec(),))
    by_name = {g.name: g for g in layout.groups}
    assert set(by_name) == {"experts", "dense"}
    paths = bus.leaf_paths(tree)
    for g in layout.groups:
        for i in g.slots:
            matched = any(p in paths[i] for p in EXPERT_LEAF_PATTERNS)
            assert matched == (g.name == "experts"), (g.name, paths[i])
    # the shared expert is NOT in the experts group (it is replicated and
    # gossips with the dense weights)
    (shared_i,) = [i for i, p in enumerate(paths) if "shared" in p]
    assert shared_i in by_name["dense"].slots
    assert by_name["experts"].gossip_every == 0  # preset default: opt out


def test_preset_group_assignment_ssm():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.models.mamba import (SSM_STATE_LEAF_PATTERNS,
                                    ssm_state_group_spec)

    model = build_model(get_smoke_config("falcon_mamba_7b"))
    tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    layout = bus.layout_of(model, 2, block_rows=8,
                           groups=(ssm_state_group_spec(),))
    by_name = {g.name: g for g in layout.groups}
    assert by_name["ssm_state"].rows > 0
    paths = bus.leaf_paths(tree)
    for i in by_name["ssm_state"].slots:
        assert any(p in paths[i] for p in SSM_STATE_LEAF_PATTERNS), paths[i]
    # projections stay dense
    for i in by_name["dense"].slots:
        assert not any(p in paths[i] for p in SSM_STATE_LEAF_PATTERNS), \
            paths[i]


def test_groups_tile_bus_contiguously_and_align():
    from repro.models.moe import expert_group_spec

    tree = _moe_like_tree(3)
    for shards in (1, 2):
        layout = bus.make_layout(tree, block_rows=8, shards=shards,
                                 groups=(expert_group_spec(),))
        quantum = layout.block_rows * shards
        cursor = 0
        for g in sorted(layout.groups, key=lambda g: g.row):
            assert g.row == cursor, (g.name, g.row, cursor)
            assert g.rows % quantum == 0, (g.name, g.rows, quantum)
            cursor += g.rows
        assert cursor == layout.rows
        # every slot lives inside its group's row range
        for g in layout.groups:
            for i in g.slots:
                s = layout.slots[i]
                assert g.row <= s.row and s.row + s.rows <= g.row + g.rows


def test_grouped_pack_unpack_roundtrip():
    from repro.models.moe import expert_group_spec

    tree = _moe_like_tree(3)
    layout = bus.make_layout(tree, block_rows=8,
                             groups=(expert_group_spec(),))
    packed = bus.pack_tree(layout, tree)
    assert packed.shape == (3, layout.rows, 128)
    back = bus.unpack_tree(layout, packed)
    for w, g in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # pad regions (alignment gaps between groups included) are zero
    flat = np.asarray(packed).reshape(3, -1)
    mask = np.ones(flat.shape[1], bool)
    for slot in layout.slots:
        mask[slot.row * 128: slot.row * 128 + slot.size] = False
    assert np.all(flat[:, mask] == 0)


def test_default_layout_is_bit_identical_and_cached():
    """gossip_groups="" must build the EXACT pre-§12 layout: same cached
    object as a plain make_layout call, same slots, same packed bytes —
    the default path cannot drift from PR 9."""
    tree = _moe_like_tree(4)
    legacy = bus.make_layout(tree, block_rows=8)
    via_none = bus.make_layout(tree, block_rows=8, groups=None)
    assert via_none is legacy  # cache identity: no groups == legacy key
    assert len(legacy.groups) == 1 and legacy.groups[0].name == "dense"
    assert not legacy.is_grouped
    # a trivial explicit catch-all is equal in layout terms (not cached
    # as the same object — different spec key — but same rows/slots)
    trivial = bus.make_layout(tree, block_rows=8,
                              groups=(GroupSpec("dense"),))
    assert not trivial.is_grouped
    assert trivial.rows == legacy.rows
    assert trivial.slots == legacy.slots
    np.testing.assert_array_equal(
        np.asarray(bus.pack_tree(trivial, tree)),
        np.asarray(bus.pack_tree(legacy, tree)))


def test_grouped_layout_cache_key_includes_specs():
    from repro.models.moe import expert_group_spec

    tree = _moe_like_tree(2)
    a = bus.make_layout(tree, block_rows=8, groups=(expert_group_spec(),))
    b = bus.make_layout(tree, block_rows=8,
                        groups=(expert_group_spec(gossip_every=4),))
    c = bus.make_layout(tree, block_rows=8, groups=(expert_group_spec(),))
    assert a is not b  # different policy -> different layout
    assert a is c      # equal specs -> cached


# ---------------------------------------------------------------------------
# feature matrix: resolve_group_specs / resolve_features / deprecations
# ---------------------------------------------------------------------------

def test_resolve_group_specs_presets_and_json():
    from repro.configs.base import RunConfig
    from repro.train import resolve_group_specs

    assert resolve_group_specs(RunConfig()) == ()
    (g,) = resolve_group_specs(RunConfig(gossip_groups="moe"))
    assert g.name == "experts" and g.gossip_every == 0
    (g,) = resolve_group_specs(RunConfig(gossip_groups="moe:4"))
    assert g.gossip_every == 4
    (g,) = resolve_group_specs(RunConfig(gossip_groups="ssm"))
    assert g.name == "ssm_state"
    gs = resolve_group_specs(RunConfig(gossip_groups="moe:2,ssm"))
    assert [g.name for g in gs] == ["experts", "ssm_state"]
    gs = resolve_group_specs(RunConfig(gossip_groups=(
        '[{"name": "hot", "match": ["embed"], "gossip_every": 2, '
        '"wire": "bf16"}]')))
    assert gs[0].name == "hot" and gs[0].wire == "bf16"
    with pytest.raises(AssertionError):
        resolve_group_specs(RunConfig(gossip_groups="bogus"))


def test_resolve_features_group_composition_matrix():
    from repro.configs.base import RunConfig
    from repro.train import resolve_features

    ok = resolve_features(RunConfig(algorithm="edm",
                                    gossip_engine="ppermute",
                                    gossip_groups="moe"))
    assert ok.packed_bus and ok.grouped
    # groups need the packed bus
    with pytest.raises(AssertionError):
        resolve_features(RunConfig(algorithm="edm", gossip_engine="shifts",
                                   gossip_groups="moe"))
    # groups replace the run-level cadence — keep gossip_every == 1
    with pytest.raises(AssertionError):
        resolve_features(RunConfig(algorithm="edm",
                                   gossip_engine="ppermute",
                                   gossip_groups="moe", gossip_every=2))
    # run-level wire/overlap stay single-group features
    with pytest.raises(AssertionError):
        resolve_features(RunConfig(algorithm="edm",
                                   gossip_engine="ppermute",
                                   gossip_groups="moe", wire="int8"))
    with pytest.raises(AssertionError):
        resolve_features(RunConfig(algorithm="edm",
                                   gossip_engine="ppermute",
                                   gossip_groups="moe", overlap="delayed"))


def test_deprecated_feature_wrappers_delegate():
    from repro.configs.base import RunConfig
    from repro.train import (resolve_features, use_overlap, use_packed_bus,
                             use_wire)

    run = RunConfig(algorithm="edm", gossip_engine="ppermute")
    feats = resolve_features(run)
    with pytest.warns(DeprecationWarning):
        assert use_packed_bus(run) == feats.packed_bus
    with pytest.warns(DeprecationWarning):
        assert use_overlap(run) == feats.overlap
    with pytest.warns(DeprecationWarning):
        assert use_wire(run) == feats.wire


# ---------------------------------------------------------------------------
# satellite: per-group cadence × period — no gcd aliasing (property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,period", [
    (1, 5), (2, 2), (2, 4), (3, 3), (4, 2), (4, 6), (5, 5), (6, 4),
])
def test_group_round_clock_visits_every_round(k, period):
    """The group round clock ``gossip_round_step(step, k) % period`` must
    cycle through EVERY schedule round — including the (k, period) pairs
    with gcd > 1 where indexing by the raw step would alias a strict
    subset of rounds forever."""
    from repro.train import gossip_round_step

    steps = range(2 * k * period)
    gossip_steps = [t for t in steps if t % k == k - 1]
    visited = {gossip_round_step(t, k) % period for t in gossip_steps}
    assert visited == set(range(period)), (k, period, visited)
    # the raw-step negative control: any gcd(k, period) > 1 would alias
    import math
    raw = {t % period for t in gossip_steps}
    if math.gcd(k, period) > 1 and k > 1:
        assert raw != set(range(period)), (k, period, raw)


def test_group_byte_model_cadence():
    """group_wire_bytes_per_step: opt-out ships zero always; slow-cycle
    ships only on steps ≡ k−1 (mod k) with the round taken from the
    group's own clock."""
    from repro.core import group_wire_bytes_per_step, ring
    from repro.core.bus import BusGroup
    from repro.core.schedule import StaticSchedule, wire_bytes_per_step

    sched = StaticSchedule(ring(8))
    dense = BusGroup("dense", row=0, rows=64, slots=(0,), gossip_every=1)
    experts = BusGroup("experts", row=64, rows=128, slots=(1,),
                       gossip_every=4)
    local = BusGroup("local", row=192, rows=8, slots=(2,), gossip_every=0)
    scheds = {"dense": sched, "experts": sched}
    per_dense = wire_bytes_per_step(sched, 0, elems_per_agent=dense.elems,
                                    engine="ppermute")
    per_exp = wire_bytes_per_step(sched, 0, elems_per_agent=experts.elems,
                                  engine="ppermute")
    for t in range(8):
        got = group_wire_bytes_per_step((dense, experts, local), scheds, t)
        assert got["local"] == 0
        assert got["dense"] == per_dense
        assert got["experts"] == (per_exp if t % 4 == 3 else 0)
        assert got["total"] == got["dense"] + got["experts"]


# ---------------------------------------------------------------------------
# per-group plans: Assumption 1, schedule overrides, opt-out
# ---------------------------------------------------------------------------

def test_make_group_plans_policies():
    from repro.configs.base import RunConfig
    from repro.models.moe import expert_group_spec
    from repro.train import (bus_layout_for, make_gossip_schedule,
                             make_group_plans, resolve_features)

    tree = _moe_like_tree(4)
    tree1 = jax.tree.map(lambda x: x[0], tree)  # Model.init: key -> params

    class _M:  # minimal Model-shaped stand-in for bus_layout_for
        def init(self, key):
            return tree1

    A = 4
    # opt-out: no schedule, no codec
    run = RunConfig(global_batch=A, algorithm="edm",
                    gossip_engine="ppermute", gossip_groups="moe")
    feats = resolve_features(run)
    layout = bus_layout_for(_M(), A, groups=feats.groups)
    sched = make_gossip_schedule(run, A)
    plans = {p.group.name: p for p in make_group_plans(run, layout, sched)}
    assert plans["experts"].sched is None and plans["experts"].wire is None
    assert plans["dense"].sched is sched

    # per-group schedule override + wire codec resolve; Assumption 1 is
    # re-checked per group at build time (check_assumption1 raises inside
    # make_group_plans on violation)
    run2 = RunConfig(global_batch=A, algorithm="edm",
                     gossip_engine="ppermute")
    layout2 = bus_layout_for(
        _M(), A, groups=(expert_group_spec(gossip_every=2, wire="int8",
                                           schedule="round_robin"),))
    plans2 = {p.group.name: p
              for p in make_group_plans(run2, layout2, sched)}
    assert plans2["experts"].sched is not sched
    assert "round_robin" in plans2["experts"].sched.name
    assert plans2["experts"].wire is not None
    plans2["experts"].sched.check_assumption1()
    assert plans2["dense"].sched is sched


# ---------------------------------------------------------------------------
# cross-layout checkpoints: 1-group save -> 2-group restore and back
# ---------------------------------------------------------------------------

def test_checkpoint_cross_group_layout(tmp_path):
    from repro.models.moe import expert_group_spec
    from repro.train import checkpoint

    tree = _moe_like_tree(4)
    l1 = bus.make_layout(tree, block_rows=8)
    l2 = bus.make_layout(tree, block_rows=8, groups=(expert_group_spec(),))
    assert l1 is not l2
    b1 = bus.pack_tree(l1, tree)
    b2 = bus.pack_tree(l2, tree)

    p = str(tmp_path / "one_group.npz")
    checkpoint.save(p, b1, layout=l1)
    # restores bit-exactly into the 2-group layout (logical trees on disk)
    got = checkpoint.load(p, jnp.zeros_like(b2), layout=l2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(b2))

    p2 = str(tmp_path / "two_group.npz")
    checkpoint.save(p2, b2, layout=l2)
    got1 = checkpoint.load(p2, jnp.zeros_like(b1), layout=l1)
    np.testing.assert_array_equal(np.asarray(got1), np.asarray(b1))


# ---------------------------------------------------------------------------
# subprocess pins: trajectory bit-identity + HLO permute count
# ---------------------------------------------------------------------------

_TRAJ_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core import bus as parambus
from repro.data import SyntheticLM
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
from repro.models import build_model
from repro.train import (build_train_step, bus_layout_for, init_state,
                         make_gossip_schedule, resolve_features)

cfg = get_smoke_config("deepseek_moe_16b")
model = build_model(cfg)
A = 8
batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8,
                    n_agents=A).sample(jax.random.PRNGKey(1), 1)
mesh = make_gossip_mesh(A)
axes = gossip_agent_axes(mesh)

def run_steps(groups, steps=3):
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.2,
                    gossip_engine="ppermute", gossip_groups=groups,
                    remat=False)
    feats = resolve_features(run)
    sched = make_gossip_schedule(run, A)
    layout = bus_layout_for(model, A, groups=feats.groups)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, sched, mesh=mesh,
                                    agent_axes=axes))
    for _ in range(steps):
        state, m = step(state, batch)
    return parambus.unpack_tree(layout, state["params"])

ref = run_steps("")
# PIN 1: an explicit trivial single-group spec is bit-identical to the
# default ("" = the PR-9 bus step)
triv = run_steps('[{"name": "dense"}]')
for w, g in zip(jax.tree.leaves(ref), jax.tree.leaves(triv)):
    np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
print("TRAJ_TRIVIAL_OK")
# PIN 2: the 2-group all-gossip layout (every group on the run schedule,
# every step) is bit-identical too — grouping permutes rows and pads
# differently but mixing/update are row-independent
g2 = run_steps("moe:1")
for w, g in zip(jax.tree.leaves(ref), jax.tree.leaves(g2)):
    np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
print("TRAJ_GROUPED_OK")
# NEGATIVE CONTROL: opt-out must change the expert trajectory (no expert
# averaging) while leaving it finite
g0 = run_steps("moe")
leaves_ref = jax.tree_util.tree_flatten_with_path(ref)[0]
leaves_g0 = jax.tree.leaves(g0)
diff = False
for (path, w), g in zip(leaves_ref, leaves_g0):
    ps = "|".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
    if any(pat in ps for pat in ("moe|w_gate", "moe|w_up", "moe|w_down")) \
            and "shared" not in ps:
        diff |= not np.array_equal(np.asarray(g), np.asarray(w))
        assert np.all(np.isfinite(np.asarray(g)))
assert diff, "opt-out did not change the expert trajectory"
print("TRAJ_OPTOUT_OK")
"""


def test_default_and_grouped_trajectory_bit_identical():
    """Acceptance pin: gossip_groups="" and the trivial/2-group all-gossip
    specs produce bit-identical parameter trajectories
    (assert_array_equal); expert opt-out diverges (negative control)."""
    r = subprocess.run([sys.executable, "-c", _TRAJ_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for pin in ("TRAJ_TRIVIAL_OK", "TRAJ_GROUPED_OK", "TRAJ_OPTOUT_OK"):
        assert pin in r.stdout


_HLO_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.data import SyntheticLM
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
from repro.models import build_model
from repro.train import build_train_step, init_state, make_gossip_schedule

cfg = get_smoke_config("deepseek_moe_16b")
model = build_model(cfg)
A = 8
batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8,
                    n_agents=A).sample(jax.random.PRNGKey(1), 1)
mesh = make_gossip_mesh(A)
axes = gossip_agent_axes(mesh)

def permutes(groups):
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.2,
                    gossip_engine="ppermute", gossip_groups=groups,
                    remat=False)
    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = build_train_step(model, run, sched, mesh=mesh, agent_axes=axes)
    hlo = jax.jit(step).lower(state, batch).compile().as_text()
    return hlo.count("collective-permute(")

base = permutes("")
opt = permutes("moe")
# ring: 2 permutes/step for the dense group; the opt-out expert rows must
# contribute ZERO collectives — same count as the whole-bus baseline
assert base == 2, base
assert opt == 2, (opt, base)
print("GROUP_HLO_OK")
"""


def test_opt_out_group_ships_zero_collectives():
    """Acceptance pin: an opt-out policy group contributes zero
    collective-permutes to the lowered train step — its rows are pure
    slices, not masked sends."""
    r = subprocess.run([sys.executable, "-c", _HLO_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "GROUP_HLO_OK" in r.stdout


# ---------------------------------------------------------------------------
# satellite: build_mixer facade aliases the make_* constructors
# ---------------------------------------------------------------------------

def test_build_mixer_modes_match_legacy_constructors():
    from repro.core import (StaticSchedule, build_mixer, make_mixer,
                            make_schedule_mixer, ring)

    topo = ring(4)
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 256))}
    # static mode == make_mixer
    np.testing.assert_array_equal(
        np.asarray(build_mixer(topo, mode="static", engine="shifts")(x)["w"]),
        np.asarray(make_mixer(topo, "shifts")(x)["w"]))
    # schedule mode == make_schedule_mixer (bare topology auto-wrapped)
    sched = StaticSchedule(topo)
    for step in (0, 1):
        np.testing.assert_array_equal(
            np.asarray(build_mixer(topo, mode="schedule",
                                   engine="shifts")(x, step)["w"]),
            np.asarray(make_schedule_mixer(sched, "shifts")(x, step)["w"]))
    with pytest.raises(ValueError):
        build_mixer(topo, mode="bogus")
