"""Production gossip path: n-ary fused combine kernel + ppermute engine.

Hypothesis-free coverage (runs everywhere):

* the n-ary ``gossip_axpy`` Pallas kernel vs its jnp oracle, f32 and bf16,
  interpret mode;
* ``mix_ppermute == mix_dense`` on every shipped topology (flat *and*
  hierarchical, split and linearized agent axes, fused and unfused combine)
  on a multi-device host-platform mesh — run in a subprocess so the forced
  device count cannot leak into this test process;
* EDM composed with the fused ppermute mixer matches the dense-mixer run.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.edm_update import gossip_axpy_flat

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


# ---------------------------------------------------------------------------
# n-ary gossip_axpy kernel vs oracle
# ---------------------------------------------------------------------------

WEIGHT_SETS = [
    (0.5, 0.25, 0.25),                      # paper's ring
    (1.0,),                                 # identity / disconnected
    (0.4, 0.3, 0.2, 0.1),                   # asymmetric 4-term
    tuple([1.0 / 6] * 6),                   # hierarchical 6-term
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("weights", WEIGHT_SETS,
                         ids=lambda w: f"n{len(w)}")
def test_gossip_axpy_flat_nary_matches_ref(weights, dtype):
    shape = (512, 128)
    ks = jax.random.split(jax.random.PRNGKey(0), len(weights))
    operands = tuple(jax.random.normal(k, shape).astype(dtype) for k in ks)
    out = gossip_axpy_flat(operands, weights, interpret=True)
    want = ref.gossip_axpy_ref(operands, weights)
    assert out.dtype == dtype
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_gossip_axpy_bf16_accumulates_in_f32():
    """bf16 path must round once (on the store), not per term: summing many
    small terms in bf16 would lose them to the large one."""
    n = 8
    big = jnp.full((512, 128), 1024.0, jnp.bfloat16)
    small = jnp.full((512, 128), 1.0, jnp.bfloat16)
    operands = (big,) + (small,) * (n - 1)
    weights = (1.0,) + (1.0,) * (n - 1)
    out = gossip_axpy_flat(operands, weights, interpret=True)
    # f32 accumulation: 1024 + 7 = 1031 → rounds to 1032 in bf16.
    # per-term bf16 accumulation would stick at 1024 (1 < ulp(1024)=8... each
    # add of 1 rounds away) — guard the f32-accumulate contract.
    np.testing.assert_allclose(np.asarray(out, np.float32), 1032.0)


@pytest.mark.parametrize("shape", [(7,), (130,), (3, 5, 17), (1000, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_gossip_axpy_arbitrary_shapes(shape, dtype):
    """ops.gossip_axpy packs any shape and returns the original layout/dtype."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    operands = tuple(jax.random.normal(k, shape).astype(dtype) for k in ks)
    weights = (0.5, 0.25, 0.25)
    out = ops.gossip_axpy(operands, weights, interpret=True)
    assert out.shape == shape and out.dtype == dtype
    want = ref.gossip_axpy_ref(operands, weights)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# mix_ppermute == mix_dense over every shipped topology
# ---------------------------------------------------------------------------

_AGREEMENT_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import (disconnected, exp_graph, fully_connected,
                        hierarchical, make_mixer, ring, torus2d)
from repro.core.mixing import mix_dense, mix_ppermute

def submesh(shape, names):
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, names)

# mirrors tests/test_core.py::TOPOLOGIES
TOPOLOGIES = [
    ring(8), ring(32), exp_graph(16), torus2d(2, 8), torus2d(4, 4),
    fully_connected(8), hierarchical(2, 16), hierarchical(4, 4, intra="ring"),
    disconnected(8),
]

for topo in TOPOLOGIES:
    A = topo.n_agents
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (A, 5)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (A, 2, 3))}
    want = mix_dense(topo, tree)
    meshes = [(submesh((A,), ("agents",)), "agents")]
    if topo.grid is not None:  # hierarchical: also the split (pod, data) mesh
        meshes.append((submesh(topo.grid, ("pod", "data")), ("pod", "data")))
    for mesh, axes in meshes:
        for fused in (False, True):
            mixer = make_mixer(topo, "ppermute", mesh=mesh, agent_axes=axes,
                               use_fused_kernel=fused)
            got = jax.jit(mixer)(tree)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(want[k]),
                    rtol=1e-5, atol=1e-6,
                    err_msg=f"{topo.name}-{A} axes={axes} fused={fused} {k}")
    print(f"AGREE {topo.name}-{A}")

# EDM composed with the fused ppermute mixer == EDM with the dense mixer
from repro.core import make_optimizer
topo = ring(8)
mesh, axes = submesh((8,), ("agents",)), "agents"
x0 = jax.random.normal(jax.random.PRNGKey(2), (8, 6))
g = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (8, 6))
runs = {}
for label, mixer in (
        ("dense", make_mixer(topo, "dense")),
        ("ppermute", make_mixer(topo, "ppermute", mesh=mesh, agent_axes=axes,
                                use_fused_kernel=True))):
    opt = make_optimizer("edm", alpha=0.05, beta=0.9, mix=mixer)
    x, st = x0, opt.init(x0)
    for _ in range(3):
        x, st = opt.step(x, g, st)
    runs[label] = x
np.testing.assert_allclose(np.asarray(runs["ppermute"]),
                           np.asarray(runs["dense"]), rtol=1e-5, atol=1e-6)
print("AGREEMENT_OK")
"""


def test_ppermute_agrees_with_dense_all_topologies():
    """Acceptance: make_mixer(engine="ppermute") matches mix_dense to 1e-5 on
    every topology in test_core.TOPOLOGIES, split and flat meshes, with and
    without the fused Pallas combine — and composes with the EDM optimizer."""
    r = subprocess.run([sys.executable, "-c", _AGREEMENT_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "AGREEMENT_OK" in r.stdout


# ---------------------------------------------------------------------------
# time-varying gossip schedules (DESIGN §4)
# ---------------------------------------------------------------------------

def _shipped_schedules():
    from repro.core import (AlternatingHierarchical, RoundRobinExp,
                            StaticSchedule, exp_graph, hierarchical, ring)
    return [
        StaticSchedule(ring(8)),
        StaticSchedule(exp_graph(16)),
        StaticSchedule(hierarchical(2, 16)),
        RoundRobinExp(8),
        RoundRobinExp(12),          # non-power-of-two n
        RoundRobinExp(32),
        RoundRobinExp(32, seed=7),  # shuffled offset order
        AlternatingHierarchical(2, 16),
        AlternatingHierarchical(4, 4, intra_every=2),
        AlternatingHierarchical(4, 8, intra="full"),
    ]


@pytest.mark.parametrize("sched", _shipped_schedules(),
                         ids=lambda s: s.name.replace("(", "-").strip(")"))
def test_schedules_satisfy_assumption1(sched):
    """Schedule form of the paper's Assumption 1: every round doubly
    stochastic with positive diagonal, period product contracting."""
    sched.check_assumption1()


def test_round_robin_exp_one_permute_per_round():
    """Acceptance: every round of the n=32 one-peer schedule carries exactly
    one nonzero-shift term (one collective-permute per step), vs the static
    exp graph's O(log n) terms per step."""
    from repro.core import RoundRobinExp, StaticSchedule, exp_graph
    sched = RoundRobinExp(32)
    assert sched.period == 5  # offsets 1, 2, 4, 8, 16
    for rnd in sched.rounds:
        assert sum(1 for t in rnd.terms if t.shift != 0) == 1, rnd
    static_terms = sum(
        1 for t in exp_graph(32).terms if t.shift != 0)
    assert static_terms >= 5  # the per-step wire cut is >= period x
    stats = sched.product_spectral_stats()
    assert stats["permutes_per_step"] == 1


def test_round_robin_period_product_matches_static_exp_mixing():
    """The one-peer round-robin period product mixes at least as fast as
    `period` applications of the static exp graph — and for power-of-two n
    it is *exact* averaging (the product of (I + R_{2^j})/2 telescopes to
    (1/n)·11^T)."""
    from repro.core import RoundRobinExp, exp_graph
    n = 32
    sched = RoundRobinExp(n)
    P = sched.period_product()
    ones = np.full((n, n), 1.0 / n)
    # power-of-two n: exact averaging after one period
    np.testing.assert_allclose(P, ones, atol=1e-12)
    # ⇒ at least the static exp graph's contraction over the same steps
    W = exp_graph(n).dense_matrix()
    W_period = np.linalg.matrix_power(W, sched.period)
    assert np.linalg.norm(P - ones, 2) <= np.linalg.norm(W_period - ones, 2) + 1e-12
    # offset order never changes the product (circulants commute)
    P_shuf = type(sched)(n, seed=123).period_product()
    np.testing.assert_allclose(P, P_shuf, atol=1e-12)


def test_round_robin_non_power_of_two_still_contracts():
    from repro.core import RoundRobinExp
    sched = RoundRobinExp(12)
    assert sched.product_spectral_gap() > 0.1


def test_schedule_mixer_threads_step_through_trainer_mixing():
    """EDM driven by a schedule mixer (traced step, lax.switch) must equal
    EDM where each step's round is applied explicitly via the dense oracle —
    the per-step W-consistency rule of DESIGN §4."""
    from repro.core import (RoundRobinExp, make_mixer, make_optimizer,
                            make_schedule_mixer)
    sched = RoundRobinExp(8)
    x0 = jax.random.normal(jax.random.PRNGKey(2), (8, 6))
    g = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (8, 6))

    # reference: rebuild the optimizer each step with that round's mixer
    x_ref, st_ref = x0, make_optimizer(
        "edm", alpha=0.05, beta=0.9,
        mix=make_mixer(sched.rounds[0], "dense")).init(x0)
    for t in range(6):
        opt = make_optimizer("edm", alpha=0.05, beta=0.9,
                             mix=make_mixer(sched.round(t), "dense"))
        x_ref, st_ref = opt.step(x_ref, g, st_ref)

    # schedule mixer with a *traced* step, stepped under jit
    smix = make_schedule_mixer(sched, "dense")

    @jax.jit
    def step_fn(x, st, t):
        opt = make_optimizer("edm", alpha=0.05, beta=0.9,
                             mix=lambda tree: smix(tree, step=t))
        return opt.step(x, g, st)

    x_s, st_s = x0, make_optimizer(
        "edm", alpha=0.05, beta=0.9, mix=lambda t: t).init(x0)
    for t in range(6):
        x_s, st_s = step_fn(x_s, st_s, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(x_s), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-6)


def test_gossip_round_step_covers_all_rounds_under_gossip_every():
    """gossip_every=k must not alias against the schedule period: the round
    clock advances per executed gossip, so every round is eventually used
    even when gcd(k, period) > 1."""
    from repro.train import gossip_round_step
    for k, period in [(5, 5), (2, 2), (4, 2), (3, 5), (1, 5)]:
        gossip_steps = [t for t in range(20 * k * period)
                        if t % k == k - 1] if k > 1 else list(range(period))
        rounds = {int(gossip_round_step(t, k)) % period for t in gossip_steps}
        assert rounds == set(range(period)), (k, period, rounds)


def test_gossip_axpy_weights_traceable():
    """The advertised contract: weights are traced data — a jit-traced
    weight array must work at the public entry point."""
    shape = (40, 9)
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    operands = tuple(jax.random.normal(k, shape) for k in ks)

    @jax.jit
    def f(w):
        return ops.gossip_axpy(operands, w, interpret=True)

    out = f(jnp.array([0.25, 0.75]))
    want = ref.gossip_axpy_ref(operands, (0.25, 0.75))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_gossip_axpy_dynamic_weights_no_retrace():
    """Per-round arity without retracing: two weight sets of one arity share
    one compiled kernel (weights are traced SMEM data, not a jit key)."""
    ops._gossip_axpy_jit.clear_cache()
    shape = (64, 33)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    operands = tuple(jax.random.normal(k, shape) for k in ks)
    for weights in [(0.5, 0.25, 0.25), (0.4, 0.4, 0.2), (1.0, 0.0, 0.0)]:
        out = ops.gossip_axpy(operands, weights, interpret=True)
        want = ref.gossip_axpy_ref(operands, weights)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
    assert ops._gossip_axpy_jit._cache_size() == 1
    # a different arity is a new kernel — exactly one more cache entry
    ops.gossip_axpy(operands[:2], (0.7, 0.3), interpret=True)
    assert ops._gossip_axpy_jit._cache_size() == 2


def test_block_rows_knob():
    """BLOCK_ROWS is tunable per call and via REPRO_BLOCK_ROWS."""
    shape = (300, 7)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    operands = tuple(jax.random.normal(k, shape) for k in ks)
    weights = (0.6, 0.4)
    want = ref.gossip_axpy_ref(operands, weights)
    for br in (8, 128, 1024):
        out = ops.gossip_axpy(operands, weights, block_rows=br,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
    code = ("import os; os.environ['REPRO_BLOCK_ROWS']='256'; "
            "from repro.kernels.edm_update import BLOCK_ROWS; "
            "assert BLOCK_ROWS == 256, BLOCK_ROWS; print('ENV_OK')")
    r = subprocess.run([sys.executable, "-c", code], cwd=REPO, env=ENV,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ENV_OK" in r.stdout


_SCHEDULE_ENGINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import (AlternatingHierarchical, RoundRobinExp,
                        StaticSchedule, exp_graph, make_schedule_mixer)
from repro.core.mixing import mix_dense

def flat_mesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("data",))

SCHEDULES = [RoundRobinExp(32), AlternatingHierarchical(4, 8),
             StaticSchedule(exp_graph(32))]

for sched in SCHEDULES:
    A = sched.n_agents
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (A, 5)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (A, 2, 3))}
    for apd in (1, 4):  # one agent per device, and blocked 32-on-8
        mesh = flat_mesh(A // apd)
        for fused in (False, True):
            mix = make_schedule_mixer(sched, "ppermute", mesh=mesh,
                                      agent_axes="data",
                                      use_fused_kernel=fused)
            for r in range(sched.period):   # every round index
                got = jax.jit(lambda t, r=r: mix(t, step=r))(tree)
                want = mix_dense(sched.rounds[r], tree)
                for k in tree:
                    np.testing.assert_allclose(
                        np.asarray(got[k]), np.asarray(want[k]),
                        rtol=1e-5, atol=1e-6,
                        err_msg=f"{sched.name} B={apd} fused={fused} "
                                f"round={r} {k}")
            # traced step routes through lax.switch over the permute plans
            t_tr = jnp.int32(sched.period + 1)
            got = jax.jit(mix)(tree, t_tr)
            want = mix_dense(sched.round(sched.period + 1), tree)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(want[k]),
                    rtol=1e-5, atol=1e-6,
                    err_msg=f"{sched.name} B={apd} fused={fused} traced")
    print(f"SCHED_AGREE {sched.name}")

# acceptance: one-peer round compiles to exactly ONE collective-permute,
# and the blocked A=32-on-8 engine emits real permutes (no shifts fallback)
sched = RoundRobinExp(32)
mix = make_schedule_mixer(sched, "ppermute", mesh=flat_mesh(32),
                          agent_axes="data")
x = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 4))}
hlo = jax.jit(lambda t: mix(t, step=0)).lower(x).compile().as_text()
assert hlo.count("collective-permute(") == 1, hlo.count("collective-permute(")

mix_b = make_schedule_mixer(sched, "ppermute", mesh=flat_mesh(8),
                            agent_axes="data")
hlo_b = jax.jit(lambda t: mix_b(t, step=0)).lower(x).compile().as_text()
assert hlo_b.count("collective-permute(") >= 1
print("SCHEDULE_ENGINES_OK")
"""


def test_schedule_engines_agree_every_round_and_blocked():
    """Acceptance: ppermute == dense oracle at every round index of every
    shipped schedule, on the 32-agent host mesh AND blocked 32-agents-on-8-
    devices (B=4), fused and unfused; the n=32 one-peer round compiles to
    exactly one collective-permute."""
    r = subprocess.run([sys.executable, "-c", _SCHEDULE_ENGINE_CODE],
                       cwd=REPO, env=ENV, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "SCHEDULE_ENGINES_OK" in r.stdout
