"""Production gossip path: n-ary fused combine kernel + ppermute engine.

Hypothesis-free coverage (runs everywhere):

* the n-ary ``gossip_axpy`` Pallas kernel vs its jnp oracle, f32 and bf16,
  interpret mode;
* ``mix_ppermute == mix_dense`` on every shipped topology (flat *and*
  hierarchical, split and linearized agent axes, fused and unfused combine)
  on a multi-device host-platform mesh — run in a subprocess so the forced
  device count cannot leak into this test process;
* EDM composed with the fused ppermute mixer matches the dense-mixer run.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.edm_update import gossip_axpy_flat

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


# ---------------------------------------------------------------------------
# n-ary gossip_axpy kernel vs oracle
# ---------------------------------------------------------------------------

WEIGHT_SETS = [
    (0.5, 0.25, 0.25),                      # paper's ring
    (1.0,),                                 # identity / disconnected
    (0.4, 0.3, 0.2, 0.1),                   # asymmetric 4-term
    tuple([1.0 / 6] * 6),                   # hierarchical 6-term
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("weights", WEIGHT_SETS,
                         ids=lambda w: f"n{len(w)}")
def test_gossip_axpy_flat_nary_matches_ref(weights, dtype):
    shape = (512, 128)
    ks = jax.random.split(jax.random.PRNGKey(0), len(weights))
    operands = tuple(jax.random.normal(k, shape).astype(dtype) for k in ks)
    out = gossip_axpy_flat(operands, weights, interpret=True)
    want = ref.gossip_axpy_ref(operands, weights)
    assert out.dtype == dtype
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_gossip_axpy_bf16_accumulates_in_f32():
    """bf16 path must round once (on the store), not per term: summing many
    small terms in bf16 would lose them to the large one."""
    n = 8
    big = jnp.full((512, 128), 1024.0, jnp.bfloat16)
    small = jnp.full((512, 128), 1.0, jnp.bfloat16)
    operands = (big,) + (small,) * (n - 1)
    weights = (1.0,) + (1.0,) * (n - 1)
    out = gossip_axpy_flat(operands, weights, interpret=True)
    # f32 accumulation: 1024 + 7 = 1031 → rounds to 1032 in bf16.
    # per-term bf16 accumulation would stick at 1024 (1 < ulp(1024)=8... each
    # add of 1 rounds away) — guard the f32-accumulate contract.
    np.testing.assert_allclose(np.asarray(out, np.float32), 1032.0)


@pytest.mark.parametrize("shape", [(7,), (130,), (3, 5, 17), (1000, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_gossip_axpy_arbitrary_shapes(shape, dtype):
    """ops.gossip_axpy packs any shape and returns the original layout/dtype."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    operands = tuple(jax.random.normal(k, shape).astype(dtype) for k in ks)
    weights = (0.5, 0.25, 0.25)
    out = ops.gossip_axpy(operands, weights, interpret=True)
    assert out.shape == shape and out.dtype == dtype
    want = ref.gossip_axpy_ref(operands, weights)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# mix_ppermute == mix_dense over every shipped topology
# ---------------------------------------------------------------------------

_AGREEMENT_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import (disconnected, exp_graph, fully_connected,
                        hierarchical, make_mixer, ring, torus2d)
from repro.core.mixing import mix_dense, mix_ppermute

def submesh(shape, names):
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, names)

# mirrors tests/test_core.py::TOPOLOGIES
TOPOLOGIES = [
    ring(8), ring(32), exp_graph(16), torus2d(2, 8), torus2d(4, 4),
    fully_connected(8), hierarchical(2, 16), hierarchical(4, 4, intra="ring"),
    disconnected(8),
]

for topo in TOPOLOGIES:
    A = topo.n_agents
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (A, 5)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (A, 2, 3))}
    want = mix_dense(topo, tree)
    meshes = [(submesh((A,), ("agents",)), "agents")]
    if topo.grid is not None:  # hierarchical: also the split (pod, data) mesh
        meshes.append((submesh(topo.grid, ("pod", "data")), ("pod", "data")))
    for mesh, axes in meshes:
        for fused in (False, True):
            mixer = make_mixer(topo, "ppermute", mesh=mesh, agent_axes=axes,
                               use_fused_kernel=fused)
            got = jax.jit(mixer)(tree)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(want[k]),
                    rtol=1e-5, atol=1e-6,
                    err_msg=f"{topo.name}-{A} axes={axes} fused={fused} {k}")
    print(f"AGREE {topo.name}-{A}")

# EDM composed with the fused ppermute mixer == EDM with the dense mixer
from repro.core import make_optimizer
topo = ring(8)
mesh, axes = submesh((8,), ("agents",)), "agents"
x0 = jax.random.normal(jax.random.PRNGKey(2), (8, 6))
g = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (8, 6))
runs = {}
for label, mixer in (
        ("dense", make_mixer(topo, "dense")),
        ("ppermute", make_mixer(topo, "ppermute", mesh=mesh, agent_axes=axes,
                                use_fused_kernel=True))):
    opt = make_optimizer("edm", alpha=0.05, beta=0.9, mix=mixer)
    x, st = x0, opt.init(x0)
    for _ in range(3):
        x, st = opt.step(x, g, st)
    runs[label] = x
np.testing.assert_allclose(np.asarray(runs["ppermute"]),
                           np.asarray(runs["dense"]), rtol=1e-5, atol=1e-6)
print("AGREEMENT_OK")
"""


def test_ppermute_agrees_with_dense_all_topologies():
    """Acceptance: make_mixer(engine="ppermute") matches mix_dense to 1e-5 on
    every topology in test_core.TOPOLOGIES, split and flat meshes, with and
    without the fused Pallas combine — and composes with the EDM optimizer."""
    r = subprocess.run([sys.executable, "-c", _AGREEMENT_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "AGREEMENT_OK" in r.stdout
