"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
with shape/dtype sweeps and hypothesis property tests.

Requires the optional ``test`` extra (hypothesis); the hypothesis-free kernel
coverage lives in tests/test_gossip_engines.py."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.edm_update import edm_update_flat, gossip_axpy_flat

# hypothesis sweeps over interpret-mode Pallas are the slow tail of the
# suite — CI's tier-1 job deselects them (-m "not slow"); a dedicated job
# runs them, and the default local `pytest -q` still includes them.
pytestmark = pytest.mark.slow

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# edm_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(512, 128), (1024, 128), (4096, 128)])
@pytest.mark.parametrize("alpha,beta", [(0.1, 0.9), (0.01, 0.0), (1e-3, 0.99)])
def test_edm_update_flat_matches_ref(shape, alpha, beta):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x, g, m, psi = (jax.random.normal(k, shape, jnp.float32) for k in ks)
    m2, psi2, phi = edm_update_flat(x, g, m, psi, alpha=alpha, beta=beta,
                                    block_rows=512, interpret=True)
    rm, rp, rphi = ref.edm_update_ref(x, g, m, psi, alpha=alpha, beta=beta)
    np.testing.assert_allclose(m2, rm, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(psi2, rp, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(phi, rphi, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(7,), (130,), (3, 5, 17), (1000, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_edm_update_arbitrary_shapes_dtypes(shape, dtype):
    """ops.edm_update pads/packs any shape and returns original layout."""
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x, g, m, psi = (jax.random.normal(k, shape).astype(dtype) for k in ks)
    m2, psi2, phi = ops.edm_update(x, g, m, psi, alpha=0.05, beta=0.9,
                                   interpret=True)
    rm, rp, rphi = ref.edm_update_ref(
        x.astype(jnp.float32), g.astype(jnp.float32),
        m.astype(jnp.float32), psi.astype(jnp.float32), alpha=0.05, beta=0.9)
    assert m2.shape == shape and m2.dtype == dtype
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(phi, np.float32), rphi,
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(m2, np.float32), rm,
                               rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 8), alpha=st.floats(1e-4, 1.0),
       beta=st.floats(0.0, 0.999), seed=st.integers(0, 2**31 - 1))
def test_edm_update_property(rows, alpha, beta, seed):
    """Property: kernel == oracle for random shapes/hparams; and β=0 reduces
    to plain ED (m' = g)."""
    shape = (rows * 512, 128)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x, g, m, psi = (jax.random.normal(k, shape, jnp.float32) for k in ks)
    m2, psi2, phi = edm_update_flat(x, g, m, psi, alpha=alpha, beta=beta,
                                    interpret=True)
    rm, rp, rphi = ref.edm_update_ref(x, g, m, psi, alpha=alpha, beta=beta)
    np.testing.assert_allclose(phi, rphi, rtol=2e-5, atol=2e-5)
    if beta == 0.0:
        np.testing.assert_allclose(m2, g, rtol=1e-6)


def test_edm_kernel_inside_optimizer():
    """make_edm(use_fused_kernel=True) must be step-for-step identical to the
    unfused optimizer."""
    from repro.core import make_mixer, ring
    from repro.core.optimizers import make_edm
    topo = ring(4)
    mix = make_mixer(topo)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 33, 5)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (4, 7))}
    grads = jax.tree.map(lambda x: 0.1 * x, params)
    o1 = make_edm(0.05, 0.9, mix, use_fused_kernel=False)
    o2 = make_edm(0.05, 0.9, mix, use_fused_kernel=True)
    s1, s2 = o1.init(params), o2.init(params)
    p1, p2 = params, params
    for _ in range(3):
        p1, s1 = o1.step(p1, grads, s1)
        p2, s2 = o2.step(p2, grads, s2)
    for k in params:
        np.testing.assert_allclose(p1[k], p2[k], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# gossip_axpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(512, 128), (2048, 128)])
def test_gossip_axpy_matches_ref(shape):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    ops3 = tuple(jax.random.normal(k, shape, jnp.float32) for k in ks)
    ws = (0.5, 0.25, 0.25)
    out = gossip_axpy_flat(ops3, ws, interpret=True)
    np.testing.assert_allclose(out, ref.gossip_axpy_ref(ops3, ws),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, K, Sq, Sk, hd, causal, window)
    (1, 4, 4, 256, 256, 64, True, 0),      # MHA causal
    (2, 8, 2, 256, 256, 64, True, 0),      # GQA 4:1
    (1, 4, 1, 128, 384, 64, False, 0),     # MQA non-causal, Sq != Sk
    (1, 2, 2, 512, 512, 128, True, 256),   # sliding window
    (1, 15, 5, 128, 128, 64, True, 0),     # smollm-style 15:5 heads
]


@pytest.mark.parametrize("case", ATTN_CASES,
                         ids=[f"c{i}" for i in range(len(ATTN_CASES))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, H, K, Sq, Sk, hd, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, hd)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              blk_q=128, blk_k=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 2), g=st.integers(1, 3), nq=st.integers(1, 3),
       nk=st.integers(1, 3), causal=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_flash_attention_property(b, g, nq, nk, causal, seed):
    """Random GQA geometry sweep vs oracle (block-multiple shapes)."""
    if causal and nk < nq:
        nk = nq  # causal needs kv to at least cover q
    H, K = 2 * g, 2
    Sq, Sk, hd = 128 * nq, 128 * nk, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, H, Sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, K, Sk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, K, Sk, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_flash_attention_window_equals_full_when_window_ge_seq():
    q = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(6), (1, 2, 256, 64))
    full = ops.flash_attention(q, k, v, causal=True, window=0, interpret=True)
    win = ops.flash_attention(q, k, v, causal=True, window=4096, interpret=True)
    np.testing.assert_allclose(full, win, rtol=1e-6, atol=1e-6)
