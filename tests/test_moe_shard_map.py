"""shard_map expert-local MoE (§Perf P1 winner) vs plain-path oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.moe import apply_moe, apply_moe_shard_map, init_moe


def _cfg(E, k, shared, cf=8.0):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=48, vocab_size=64,
                       n_experts=E, experts_per_token=k,
                       n_shared_experts=shared, capacity_factor=cf,
                       dtype="float32")


@pytest.mark.parametrize("E,k,shared", [(4, 1, 0), (8, 2, 1), (16, 4, 2)])
def test_shard_map_moe_matches_plain(E, k, shared):
    cfg = _cfg(E, k, shared)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    ref, aux_ref = apply_moe(p, cfg, x, 1e-6)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got, aux = jax.jit(
        lambda p, x: apply_moe_shard_map(p, cfg, x, 1e-6, mesh))(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)


def test_shard_map_moe_grad_finite():
    """The shard_map path must be differentiable (training usability)."""
    cfg = _cfg(4, 2, 1)
    p = init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 32))
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def loss(p):
        y, aux = apply_moe_shard_map(p, cfg, x, 1e-6, mesh)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
    assert float(jnp.max(jnp.abs(g["w_gate"]))) > 0
