"""Serving path (DESIGN §10): paged KV cache + continuous batching.

* paged-vs-dense decode equivalence — the paged engine's logits match the
  dense reference to float32 rounding and greedy tokens are EXACTLY equal,
  across ragged slot batches, for the dense-attention, GQA and
  sliding-window(ring) variants;
* Pallas paged decode-attention vs the dense oracle on ragged batches,
  including the masked-tail contract (NaN-poisoned unallocated pages
  never reach the output);
* page-allocator admit/advance/release trajectory invariants;
* layout-driven cache growth for the fixed-batch reference path;
* consensus export: per-leaf agent mean, loaded under ``serve_param_specs``
  and generating identically (subprocess ``--agents pod`` training run).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import greedy_generate, grow_caches, serve_param_specs
from repro.serve.paged_cache import (NULL_PAGE, PageAllocator,
                                     PagedCacheConfig, init_paged_pools)
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   poisson_load, run_fixed_batch)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

PROMPTS = (5, 12, 20)          # ragged: straddles page and window boundaries


def _variant(name):
    import dataclasses
    cfg = get_smoke_config("smollm_360m")
    window = 0
    if name == "gqa":
        cfg = dataclasses.replace(cfg, n_kv_heads=2)
    elif name == "window":
        window = 16            # < max prompt: exercises the ring wrap
    return cfg, window


def _pcfg(window=0, max_slots=4):
    ctx = window or 64
    return PagedCacheConfig(
        page_size=8, num_pages=1 + max_slots * (-(-ctx // 8)),
        max_slots=max_slots, max_context=ctx, window=window)


def _requests(cfg, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, (S,))
                    .astype(np.int32),
                    max_new=max_new, arrival=0.0)
            for i, S in enumerate(PROMPTS)]


# ---------------------------------------------------------------------------
# paged vs dense: logits bit-exact on ragged slot batches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["dense", "gqa", "window"])
def test_paged_logits_match_dense(variant):
    """For every slot of a ragged batch, every decode step's logits from
    the paged path match the dense reference's to float32 rounding, and
    the greedy argmax is EXACTLY equal.  Page-padding columns contribute
    exactly 0.0 under softmax (−inf mask → exp underflow), but the padded
    attention width changes XLA's reduction splitting, so the last ulp of
    the float sums can differ — token-level exactness is the serving
    contract (asserted here per step and end-to-end below)."""
    cfg, window = _variant(variant)
    model = build_model(cfg, decode_window=window)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg)
    eng = ContinuousBatchingEngine(model, params, _pcfg(window))
    for r in reqs:
        assert eng.try_admit(r)

    # dense side: per-request caches at each request's own exact length
    dense = []
    for r in reqs:
        logits, caches = model.prefill(
            params, {"tokens": jnp.asarray(r.tokens)[None]})
        L = int(r.tokens.shape[0])
        caches = grow_caches(model, caches, 1, window or L + r.max_new)
        dense.append({"caches": caches, "pos": L,
                      "tok": jnp.argmax(logits[:, -1].astype(jnp.float32),
                                        -1)[:, None].astype(jnp.int32)})

    pt, _ = eng.alloc.device_tables()
    for step in range(4):
        lens = eng.alloc.lengths
        kv = np.where(eng.alloc.active, lens + 1, 0).astype(np.int32)
        if window:
            kv = np.minimum(kv, window).astype(np.int32)
        paged_logits, eng.pools = model.decode_step_paged(
            params, eng.pools, jnp.asarray(eng.tok), jnp.asarray(lens),
            pt, jnp.asarray(kv))
        for i, d in enumerate(dense):
            ref_logits, d["caches"] = model.decode_step(
                params, d["caches"], d["tok"],
                jnp.asarray(d["pos"], jnp.int32))
            got = np.asarray(paged_logits[i], np.float32)
            want = np.asarray(ref_logits[0], np.float32)
            np.testing.assert_allclose(
                got, want, atol=1e-4, rtol=1e-3,
                err_msg=f"{variant}: slot {i} step {step} logits diverged")
            assert got.argmax() == want.argmax(), \
                f"{variant}: slot {i} step {step} greedy token diverged"
            d["tok"] = jnp.argmax(ref_logits[:, -1].astype(jnp.float32),
                                  -1)[:, None].astype(jnp.int32)
            d["pos"] += 1
            eng.tok[i, 0] = int(d["tok"][0, 0])
            eng.alloc.advance(i)


@pytest.mark.parametrize("attn_impl", ["ref", "pallas"])
def test_engine_tokens_match_dense_reference(attn_impl):
    """End-to-end continuous engine == per-request greedy_generate,
    token-for-token, on a Poisson trace (both attention backends)."""
    cfg, window = _variant("dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, _pcfg(), attn_impl=attn_impl)
    reqs = poisson_load(6, rate=500.0, vocab=cfg.vocab_size,
                        prompt_buckets=(12, 20),
                        new_token_buckets=(4, 9), seed=3)
    eng.run(reqs)
    for r in reqs:
        ref = np.asarray(greedy_generate(
            model, params, {"tokens": jnp.asarray(r.tokens)[None]},
            n_steps=r.max_new))[0]
        np.testing.assert_array_equal(ref, eng.completed[r.rid])


def test_paged_never_reads_unallocated_pages():
    """Masked-tail contract: NaN-poison every page no live slot owns — live
    slots' logits are unchanged and finite, so neither the gather path nor
    the Pallas index map can have touched an unallocated page's data.  (The
    null page stays clean: page-table tail entries point at it and its
    rows carry exactly-zero softmax weight — 0.0 × finite is the identity,
    0.0 × NaN is not, so "never read" for it means weight-0, not
    untouched-by-gather.)"""
    cfg, _ = _variant("dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, _pcfg())
    for r in _requests(cfg):
        assert eng.try_admit(r)
    lens = eng.alloc.lengths
    kv = np.where(eng.alloc.active, lens + 1, 0).astype(np.int32)
    pt, _ = eng.alloc.device_tables()
    clean, _ = model.decode_step_paged(
        params, eng.pools, jnp.asarray(eng.tok), jnp.asarray(lens), pt,
        jnp.asarray(kv))

    owned = set(np.asarray(pt)[np.asarray(eng.alloc.active)].reshape(-1)
                .tolist()) | {NULL_PAGE}
    unallocated = [p for p in range(eng.pcfg.num_pages) if p not in owned]
    poisoned = jax.tree.map(
        lambda pool: pool.at[:, jnp.asarray(unallocated)].set(jnp.nan),
        eng.pools)
    dirty, _ = model.decode_step_paged(
        params, poisoned, jnp.asarray(eng.tok), jnp.asarray(lens), pt,
        jnp.asarray(kv))
    live = np.asarray(eng.alloc.active)
    assert np.isfinite(np.asarray(dirty, np.float32)[live]).all()
    np.testing.assert_array_equal(np.asarray(clean, np.float32)[live],
                                  np.asarray(dirty, np.float32)[live])


def test_paged_kernel_matches_oracle_ragged():
    """Pallas kernel vs the gather+sdpa oracle on a ragged batch with an
    idle slot, GQA head-sharing and NaN-poisoned unallocated pages."""
    from repro.kernels.ops import paged_attention
    from repro.kernels.ref import paged_attention_ref

    rng = np.random.default_rng(0)
    B, K, G, hd = 4, 2, 3, 16
    page_size, num_pages, n_pages = 8, 12, 3
    q = jnp.asarray(rng.normal(size=(B, K, G, hd)).astype(np.float32))
    kp = rng.normal(size=(num_pages, page_size, K, hd)).astype(np.float32)
    vp = rng.normal(size=(num_pages, page_size, K, hd)).astype(np.float32)
    kv_len = np.array([5, 0, 24, 17], np.int32)     # idle slot 1; full slot 2
    pt = np.zeros((B, n_pages), np.int32)
    used = {0: [1], 2: [2, 3, 4], 3: [5, 6, 7]}
    for b, pages in used.items():
        pt[b, :len(pages)] = pages
    alloc = {p for ps in used.values() for p in ps}
    for p in range(num_pages):
        if p not in alloc:
            kp[p] = np.nan
            vp[p] = np.nan
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    pt_j, len_j = jnp.asarray(pt), jnp.asarray(kv_len)
    out = paged_attention(q, kp, vp, pt_j, len_j, page_size=page_size)
    ref = paged_attention_ref(q, jnp.nan_to_num(kp), jnp.nan_to_num(vp),
                              pt_j, len_j, page_size=page_size)
    live = np.array([0, 2, 3])
    assert jnp.isfinite(out).all()
    assert (out[1] == 0).all()                       # idle slot: zero tile
    assert np.allclose(np.asarray(out)[live], np.asarray(ref)[live],
                       atol=1e-5)


# ---------------------------------------------------------------------------
# allocator units
# ---------------------------------------------------------------------------

def test_allocator_admit_evict_trajectory():
    pcfg = PagedCacheConfig(page_size=8, num_pages=6, max_slots=3,
                            max_context=24)          # 5 usable pages
    al = PageAllocator(pcfg)
    assert al.pages_in_use == 0 and al.n_active == 0
    s0 = al.admit(context_len=9, prompt_len=5)       # 2 pages
    s1 = al.admit(context_len=24, prompt_len=20)     # 3 pages
    assert al.pages_in_use == 5 and al.n_active == 2
    # disjointness + no null page handed out
    used = np.concatenate([al.page_table[s0], al.page_table[s1]])
    used = used[used != NULL_PAGE]
    assert NULL_PAGE not in used.tolist()
    assert len(set(used.tolist())) == len(used)
    # a slot is still free but the page pool is exhausted
    assert al.free_slots and not al.can_admit(1)
    al.advance(s1)
    assert al.lengths[s1] == 21
    al.release(s1)                                   # pages come back
    assert al.n_active == 1 and al.pages_in_use == 2
    assert (al.page_table[s1] == NULL_PAGE).all() and al.lengths[s1] == 0
    assert al.can_admit(24)
    with pytest.raises(AssertionError):
        al.release(s1)                               # double release
    al.release(s0)
    assert al.pages_in_use == 0 and al.n_active == 0
    assert len(al.free_pages) == pcfg.num_pages - 1  # null page never freed


def test_allocator_ring_mode_owns_whole_ring():
    pcfg = PagedCacheConfig(page_size=8, num_pages=16, max_slots=2,
                            max_context=128, window=16)
    al = PageAllocator(pcfg)
    assert pcfg.pages_per_slot == 2
    assert al.pages_needed(context_len=5) == 2       # whole ring up front
    s = al.admit(context_len=100, prompt_len=30)     # > window: legal (ring)
    assert al.lengths[s] == 30                       # TRUE absolute length
    for _ in range(70):
        al.advance(s)
    assert al.lengths[s] == 100


def test_pagedcacheconfig_validation():
    with pytest.raises(AssertionError):
        PagedCacheConfig(page_size=6, num_pages=8, max_slots=1,
                         max_context=16)             # not 8-row aligned
    with pytest.raises(AssertionError):
        PagedCacheConfig(page_size=8, num_pages=16, max_slots=1,
                         max_context=64, window=20)  # window % page != 0
    with pytest.raises(AssertionError):
        PagedCacheConfig(page_size=8, num_pages=3, max_slots=1,
                         max_context=64)             # pool < 1 slot + null


# ---------------------------------------------------------------------------
# layout-driven cache growth (fixed-batch reference path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["falcon_mamba_7b", "jamba_1_5_large_398b"])
def test_grow_caches_leaves_length_free_leaves_alone(arch):
    """SSM/conv state has no sequence axis: growth must pass it through
    bit-identically (the name-matching growth this replaces could silently
    mis-grow any leaf whose dim happened to equal the prompt length)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    _, caches = model.prefill(params, {"tokens": toks})
    grown = grow_caches(model, caches, 2, 6 + 4)
    flat_c = jax.tree_util.tree_leaves_with_path(caches)
    flat_g = dict(jax.tree_util.tree_leaves_with_path(grown))
    n_grown = 0
    for path, c in flat_c:
        g = flat_g[path]
        if g.shape == c.shape:
            np.testing.assert_array_equal(np.asarray(c, np.float32),
                                          np.asarray(g, np.float32))
        else:
            n_grown += 1
    if cfg.family == "hybrid":
        assert n_grown > 0                           # attn positions grew
    else:
        assert n_grown == 0                          # pure SSM: nothing to


def test_fixed_batch_baseline_counts_only_requested_tokens():
    cfg, _ = _variant("dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, max_new=4)
    reqs[0].max_new = 9                              # head-of-line chunk
    m = run_fixed_batch(model, params, reqs, batch_size=len(reqs))
    assert m["tokens"] == sum(r.max_new for r in reqs)
    assert m["steps"] == 9                           # max(max_new) for all


# ---------------------------------------------------------------------------
# consensus export (train -> serve handoff)
# ---------------------------------------------------------------------------

def test_consensus_export_is_agent_mean(tmp_path):
    from repro.train import checkpoint

    rng = np.random.default_rng(0)
    A = 4
    params = {"embed": rng.normal(size=(A, 7, 3)).astype(np.float32),
              "blocks": ({"w": rng.normal(size=(A, 2, 5)).astype(np.float32)},)}
    state = {"params": params, "opt": {"m": jax.tree.map(np.zeros_like,
                                                         params)},
             "step": np.int32(3)}
    src, dst = str(tmp_path / "train.npz"), str(tmp_path / "consensus.npz")
    checkpoint.save_state(src, state)
    checkpoint.export_consensus(src, dst)
    got = np.load(dst)
    assert set(got.files) == {"embed", "blocks|0|w"}  # params only, no axis
    np.testing.assert_array_equal(
        got["embed"],
        params["embed"].mean(axis=0, dtype=np.float64).astype(np.float32))
    like = {"embed": jax.ShapeDtypeStruct((7, 3), np.float32),
            "blocks": ({"w": jax.ShapeDtypeStruct((2, 5), np.float32)},)}
    back = checkpoint.load_consensus(dst, like)
    np.testing.assert_array_equal(back["embed"], got["embed"])


def test_consensus_export_from_pod_run_serves(tmp_path):
    """Acceptance: a checkpoint from an ``--agents pod`` (FSDP-sharded)
    training run exports its consensus, loads under ``serve_param_specs``
    on the serving mesh, and generates identically to averaging the
    gathered-layout agent params directly — the checkpoint being logical/
    sharding-independent is what makes both routes the same bytes."""
    from repro.train import checkpoint

    ckpt = str(tmp_path / "pod.npz")
    env = {**ENV, "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm_360m",
         "--smoke", "--steps", "2", "--agents", "pod", "--pods", "2",
         "--seq", "16", "--gossip-engine", "ppermute", "--ckpt", ckpt],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]

    cons = str(tmp_path / "consensus.npz")
    checkpoint.export_consensus(ckpt, cons)

    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_c = jax.tree.map(jnp.asarray,
                            checkpoint.load_consensus(cons, like))

    # gathered-layout route: mean the stacked agent params of the raw
    # checkpoint directly (float64 accumulate, one rounding — as export)
    data = np.load(ckpt)
    direct = {}
    for k in data.files:
        if k.startswith("params|"):
            leaf = data[k]
            direct[k[len("params|"):]] = (
                leaf.mean(axis=0, dtype=np.float64).astype(leaf.dtype))
    flat, _ = jax.tree_util.tree_flatten_with_path(params_c)
    for path, leaf in flat:
        key = "|".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        np.testing.assert_array_equal(np.asarray(leaf), direct[key])

    # load under the serving TP specs and generate
    from jax.sharding import NamedSharding
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = serve_param_specs(model, fsdp=False, multi_pod=False)
    sharded = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        params_c, specs)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    out_sharded = greedy_generate(model, sharded, {"tokens": toks}, 5)
    out_plain = greedy_generate(model, params_c, {"tokens": toks}, 5)
    np.testing.assert_array_equal(np.asarray(out_sharded),
                                  np.asarray(out_plain))
