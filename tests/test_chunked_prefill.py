"""Chunked prefill fused into the decode dispatch (DESIGN §11).

* token-exactness — the chunked engine's greedy output is EXACTLY the
  dense reference's (``greedy_generate``) and the legacy per-request
  engine's, across dense / GQA / sliding-window(ring) variants and both
  attention backends, including under a tight per-step token budget and
  a chunk width that is not a page multiple;
* chunk-by-chunk prefill logits match the one-shot dense prefill to
  float32 rounding at every prompt position;
* the Pallas paged prefill-attention kernel vs the gather+sdpa oracle vs
  a brute-force dense truth, on ragged chunk boundaries, ring wrap
  points and a NaN-poisoned pool (unallocated pages are never read);
* allocator invariants for interleaved chunked prefill + decode — a
  deterministic trajectory plus a hypothesis sweep (``slow``), linear
  and ring modes;
* compile accounting — the legacy per-length LRU really bounds the jit
  cache (evicted lengths recompile on return) and the chunked engine's
  ``compile_count`` is CONSTANT across prompt-length distributions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import greedy_generate
from repro.serve.paged_cache import (NULL_PAGE, PageAllocator,
                                     PagedCacheConfig, init_paged_pools)
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   poisson_load)

PROMPTS = (5, 12, 20)          # ragged: straddles page and window boundaries


def _variant(name):
    cfg = get_smoke_config("smollm_360m")
    window = 0
    if name == "gqa":
        cfg = dataclasses.replace(cfg, n_kv_heads=2)
    elif name == "window":
        window = 16            # < max prompt: exercises the ring wrap
    return cfg, window


def _pcfg(window=0, max_slots=4):
    ctx = window or 64
    return PagedCacheConfig(
        page_size=8, num_pages=1 + max_slots * (-(-ctx // 8)),
        max_slots=max_slots, max_context=ctx, window=window)


def _requests(cfg, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, (S,))
                    .astype(np.int32),
                    max_new=max_new, arrival=0.0)
            for i, S in enumerate(PROMPTS)]


# ---------------------------------------------------------------------------
# end-to-end token-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attn_impl", ["ref", "pallas"])
@pytest.mark.parametrize("variant", ["dense", "gqa", "window"])
def test_chunked_engine_tokens_match_dense_reference(variant, attn_impl):
    """Chunked engine == per-request greedy_generate, token-for-token, on
    an exact-length Poisson trace (the distribution the legacy path can't
    afford), with exactly TWO compiles (mixed + decode-only)."""
    cfg, window = _variant(variant)
    model = build_model(cfg, decode_window=window)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, _pcfg(window),
                                   attn_impl=attn_impl, prefill_chunk=8)
    reqs = poisson_load(6, rate=500.0, vocab=cfg.vocab_size,
                        prompt_buckets=(12, 20), new_token_buckets=(4, 9),
                        prompt_dist="exact", seed=3)
    metrics = eng.run(reqs)
    for r in reqs:
        ref = np.asarray(greedy_generate(
            model, params, {"tokens": jnp.asarray(r.tokens)[None]},
            n_steps=r.max_new))[0]
        np.testing.assert_array_equal(ref, eng.completed[r.rid])
    assert metrics["compile_count"] == 2
    assert metrics["ttft_p99_ms"] is not None
    assert metrics["queue_p99_ms"] is not None


@pytest.mark.parametrize("variant", ["dense", "window"])
def test_chunked_engine_matches_legacy_engine(variant):
    """Chunked and legacy per-request engines emit IDENTICAL tokens for
    the same trace — chunking is a scheduling change, not a math change.
    Also pins the budgeted path (max_step_tokens) and a chunk width that
    is not a page multiple."""
    cfg, window = _variant(variant)
    model = build_model(cfg, decode_window=window)
    params = model.init(jax.random.PRNGKey(0))
    reqs = poisson_load(6, rate=500.0, vocab=cfg.vocab_size,
                        prompt_buckets=(12, 20), new_token_buckets=(4, 9),
                        seed=5)
    legacy = ContinuousBatchingEngine(model, params, _pcfg(window))
    legacy.run(reqs)
    for chunk, mst in ((8, None), (5, 7)):
        eng = ContinuousBatchingEngine(model, params, _pcfg(window),
                                       prefill_chunk=chunk,
                                       max_step_tokens=mst)
        eng.run(reqs)
        for r in reqs:
            np.testing.assert_array_equal(
                legacy.completed[r.rid], eng.completed[r.rid],
                err_msg=f"{variant}: chunk={chunk} mst={mst} rid={r.rid}")


@pytest.mark.parametrize("variant", ["dense", "gqa", "window"])
def test_chunk_by_chunk_matches_full_prefill(variant):
    """Driving ``prefill_chunk_paged`` chunk by chunk over a prompt
    reproduces the one-shot dense prefill's logits at EVERY position to
    float32 rounding (and the argmax exactly) — the padded tail of the
    last chunk contributes nothing."""
    cfg, window = _variant(variant)
    model = build_model(cfg, decode_window=window)
    params = model.init(jax.random.PRNGKey(0))
    S, C = 20, 8
    rng = np.random.default_rng(7)
    tokens = rng.integers(0, cfg.vocab_size, (S,)).astype(np.int32)
    # dense truth: model.prefill returns only the LAST position's logits,
    # so build the per-position row from prefix prefills
    dense = []
    for p in range(S):
        lg, _ = model.prefill(
            params, {"tokens": jnp.asarray(tokens[:p + 1])[None]})
        dense.append(np.asarray(lg[0, -1], np.float32))
    dense = np.stack(dense)

    pcfg = _pcfg(window)
    alloc = PageAllocator(pcfg)
    pools = init_paged_pools(cfg, pcfg)
    slot = alloc.admit(S, S, chunked=True)
    pt_row = jnp.asarray(alloc.page_table[slot])
    got = []
    for cur in range(0, S, C):
        n = min(C, S - cur)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = tokens[cur:cur + n]
        logits, pools = model.prefill_chunk_paged(
            params, pools, jnp.asarray(chunk), pt_row,
            jnp.asarray(cur, jnp.int32), jnp.asarray(n, jnp.int32))
        got.append(np.asarray(logits[0, :n], np.float32))
        alloc.advance_prefill(slot, n)
    assert not alloc.prefilling[slot]
    got = np.concatenate(got)
    np.testing.assert_allclose(got, dense, atol=1e-4, rtol=1e-3)
    np.testing.assert_array_equal(got.argmax(-1), dense.argmax(-1))


# ---------------------------------------------------------------------------
# kernel vs oracle vs dense truth
# ---------------------------------------------------------------------------

# (window, chunk_start, C, chunk_len): linear first/mid/ragged-last chunks,
# ring before/at/long-after the wrap, ragged ring tails, C == window
KERNEL_CASES = [
    (0, 0, 4, 4), (0, 4, 4, 4), (0, 9, 4, 3), (0, 20, 4, 1),
    (8, 0, 4, 4), (8, 4, 4, 4), (8, 7, 4, 4), (8, 8, 4, 4),
    (8, 13, 4, 3), (8, 37, 4, 2), (8, 37, 8, 8),
]


@pytest.mark.parametrize("window,start,C,clen", KERNEL_CASES)
def test_prefill_kernel_matches_oracle_and_truth(window, start, C, clen):
    """Pallas prefill kernel == gather+sdpa oracle == brute-force dense
    ``sdpa_ref`` on NaN-poisoned pools (every pool row the slot does not
    own is NaN — finite output proves neither path read one), with GQA
    head sharing and ragged chunk tails."""
    from repro.kernels.ops import paged_prefill_attention
    from repro.kernels.ref import paged_prefill_attention_ref
    from repro.models.attention import sdpa_ref

    rng = np.random.default_rng(0)
    page_size, n_pages, num_pages = 4, 6, 16
    K, G, hd = 2, 2, 8
    H = K * G
    k_hist = rng.standard_normal((start, K, hd)).astype(np.float32)
    v_hist = rng.standard_normal((start, K, hd)).astype(np.float32)
    k_pool = np.full((num_pages, page_size, K, hd), np.nan, np.float32)
    v_pool = np.full((num_pages, page_size, K, hd), np.nan, np.float32)
    n_slot_pages = (window // page_size) if window else n_pages
    phys = rng.choice(np.arange(1, num_pages), size=n_slot_pages,
                      replace=False)
    pt_row = np.zeros((n_pages,), np.int32)
    pt_row[:n_slot_pages] = phys
    # null page is a live write sink (clamped reads see weight-0 rows)
    k_pool[NULL_PAGE] = 0.0
    v_pool[NULL_PAGE] = 0.0
    for p in range(start):
        row = p % window if window else p
        pg, r = row // page_size, row % page_size
        k_pool[pt_row[pg], r] = k_hist[p]
        v_pool[pt_row[pg], r] = v_hist[p]

    q = rng.standard_normal((1, C, H, hd)).astype(np.float32)
    k_c = rng.standard_normal((1, C, K, hd)).astype(np.float32)
    v_c = rng.standard_normal((1, C, K, hd)).astype(np.float32)

    ref = paged_prefill_attention_ref(
        q, k_c, v_c, jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(pt_row), start, clen, window=window)
    ker = paged_prefill_attention(
        q, k_c, v_c, jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(pt_row), jnp.asarray(start, jnp.int32),
        jnp.asarray(clen, jnp.int32), page_size=page_size, window=window)
    ref = np.asarray(ref)[:, :clen]
    ker = np.asarray(ker)[:, :clen]
    assert np.isfinite(ref).all(), "oracle read a poisoned page"
    assert np.isfinite(ker).all(), "kernel read a poisoned page"
    np.testing.assert_allclose(ker, ref, atol=2e-5)
    # brute-force dense truth over history + the real chunk rows
    k_all = np.concatenate([k_hist, k_c[0, :clen]])[None]
    v_all = np.concatenate([v_hist, v_c[0, :clen]])[None]
    truth = sdpa_ref(jnp.asarray(q[:, :clen]), jnp.asarray(k_all),
                     jnp.asarray(v_all), causal=True, window=window,
                     q_offset=start)
    np.testing.assert_allclose(np.asarray(truth), ref, atol=2e-5)


# ---------------------------------------------------------------------------
# allocator: interleaved chunked prefill + decode
# ---------------------------------------------------------------------------

def test_allocator_chunked_trajectory():
    pcfg = PagedCacheConfig(page_size=8, num_pages=8, max_slots=3,
                            max_context=24)
    al = PageAllocator(pcfg)
    d = al.admit(10, 6)                       # legacy: rows live immediately
    s = al.admit(20, 17, chunked=True)        # 3 pages reserved up front
    assert al.pages_in_use == 2 + 3
    assert al.prefilling[s] and not al.prefilling[d]
    assert al.lengths[s] == 0 and al.prefill_cursor[s] == 0
    # mid-prefill slots are masked out of the decode dispatch
    pt, _ = al.decode_tables()
    assert (np.asarray(pt)[s] == NULL_PAGE).all()
    assert (np.asarray(pt)[d] != NULL_PAGE).any()
    # but their real pages stay visible to the chunk path
    assert (al.page_table[s] != NULL_PAGE).sum() == 3
    with pytest.raises(AssertionError):
        al.advance(s)                         # no decode while prefilling
    al.advance_prefill(s, 8)
    al.advance(d)                             # decode interleaves freely
    assert al.lengths[s] == 8 == al.prefill_cursor[s]
    with pytest.raises(AssertionError):
        al.advance_prefill(s, 10)             # cursor past prompt_len
    al.advance_prefill(s, 9)                  # ragged last chunk
    assert not al.prefilling[s] and al.lengths[s] == 17
    pt, _ = al.decode_tables()
    assert (np.asarray(pt)[s] != NULL_PAGE).any()
    al.advance(s)                             # now a decode slot
    with pytest.raises(AssertionError):
        al.advance_prefill(s, 1)              # prefill is over
    al.release(s)
    assert not al.prefilling[s] and al.prefill_cursor[s] == 0
    assert al.pages_in_use == 2


@pytest.mark.slow
@pytest.mark.parametrize("window", [0, 16])
def test_allocator_chunked_interleaved_property(window):
    """Random interleavings of chunked admits, legacy admits, prefill
    advances, decode advances and releases preserve the allocator
    invariants (hypothesis sweep; linear and ring modes)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    pcfg = PagedCacheConfig(page_size=8, num_pages=13, max_slots=4,
                            max_context=32, window=window)

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2 ** 30)),
                    max_size=60),
           st.integers(0, 2 ** 30))
    def run(ops, seed):
        rng = np.random.default_rng(seed)
        al = PageAllocator(pcfg)
        filling, decoding = [], []
        for op, r in ops:
            if op == 0 or op == 1:                        # admit
                S = 1 + r % 24
                ctx = min(S + rng.integers(0, 8), pcfg.max_context)
                ctx = max(ctx, S) if not window else S + int(rng.integers(0, 8))
                if not al.can_admit(ctx):
                    continue
                chunked = op == 0
                slot = al.admit(ctx, S, chunked=chunked)
                (filling if chunked else decoding).append(slot)
            elif op == 2 and filling:                     # prefill chunk
                slot = filling[r % len(filling)]
                left = int(al.prompt_len[slot] - al.prefill_cursor[slot])
                al.advance_prefill(slot, 1 + r % left)
                if not al.prefilling[slot]:
                    filling.remove(slot)
                    decoding.append(slot)
            elif op == 3 and decoding:                    # decode token
                slot = decoding[r % len(decoding)]
                if window or al.lengths[slot] < pcfg.max_context:
                    al.advance(slot)
            elif op == 4 and (filling or decoding):       # release
                pool = filling if (r % 2 == 0 and filling) else decoding
                if not pool:
                    pool = filling or decoding
                slot = pool[r % len(pool)]
                al.release(slot)
                pool.remove(slot)
            # -- invariants ----------------------------------------------
            assert al.prefilling[al.prefilling].size == len(filling)
            assert not (al.prefilling & ~al.active).any()
            assert (al.prefill_cursor <= al.prompt_len).all()
            assert (al.lengths[al.prefilling]
                    == al.prefill_cursor[al.prefilling]).all()
            owned = al.page_table[al.active]
            owned = owned[owned != NULL_PAGE]
            assert len(set(owned.tolist())) == len(owned)   # disjoint
            assert al.pages_in_use == len(owned)
            pt, _ = al.decode_tables()
            assert (np.asarray(pt)[al.prefilling] == NULL_PAGE).all()
        for slot in filling + decoding:
            al.release(slot)
        assert al.pages_in_use == 0 and al.n_active == 0

    run()


def test_prefill_chunk_validation():
    cfg, window = _variant("window")
    model = build_model(cfg, decode_window=window)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):   # chunk would self-collide in ring
        ContinuousBatchingEngine(model, params, _pcfg(window),
                                 prefill_chunk=window + 1)
    with pytest.raises(AssertionError):
        ContinuousBatchingEngine(model, params, _pcfg(window),
                                 prefill_chunk=0)
    with pytest.raises(AssertionError):
        ContinuousBatchingEngine(model, params, _pcfg(window),
                                 prefill_chunk=8, max_step_tokens=0)


# ---------------------------------------------------------------------------
# compile accounting
# ---------------------------------------------------------------------------

def test_legacy_prefill_cache_lru_is_size_capped():
    """The legacy path's per-length jit cache really evicts: with cap 4,
    a third distinct prompt length evicts the first (prefill + scatter
    entries), so re-admitting it recompiles; a still-cached length does
    not."""
    cfg, _ = _variant("dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, _pcfg(),
                                   prefill_cache_cap=4)

    def admit(S, rid):
        # max_new=1: the prefill token completes the request immediately,
        # so the slot frees and only compile accounting accumulates
        r = Request(rid=rid, tokens=np.arange(S, dtype=np.int32) % 17,
                    max_new=1, arrival=0.0)
        assert eng.try_admit(r)

    admit(5, 0)                    # prefill(5)+scatter(1p)      -> 2
    admit(12, 1)                   # prefill(12)+scatter(2p)     -> 4
    assert eng.compile_count == 4
    admit(12, 2)                   # both cached                 -> 4
    assert eng.compile_count == 4
    admit(20, 3)                   # prefill(20)+scatter(3p) evicts length-5
    assert eng.compile_count == 6
    admit(12, 4)                   # still cached (LRU-refreshed)
    assert eng.compile_count == 6
    admit(5, 5)                    # evicted: BOTH entries rebuilt
    assert eng.compile_count == 8


def test_chunked_compile_count_constant_across_distributions():
    """The chunked engine compiles exactly twice (mixed + decode-only) no
    matter the prompt-length distribution — bucketed or an exact-length
    continuum — and ``reset()`` keeps the compiles warm."""
    cfg, _ = _variant("dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, _pcfg(), prefill_chunk=8)
    for dist, seed in (("bucket", 0), ("exact", 1), ("exact", 2)):
        eng.reset()
        reqs = poisson_load(5, rate=500.0, vocab=cfg.vocab_size,
                            prompt_buckets=(9, 21),
                            new_token_buckets=(4, 7),
                            prompt_dist=dist, seed=seed)
        metrics = eng.run(reqs)
        assert metrics["compile_count"] == 2, (dist, seed)


def test_poisson_exact_prompt_dist():
    """``prompt_dist="exact"`` draws a length continuum over the bucket
    span — lengths outside the bucket set appear, none outside the span;
    arrivals and budgets are unaffected."""
    reqs = poisson_load(64, rate=100.0, vocab=64,
                        prompt_buckets=(8, 24), new_token_buckets=(4,),
                        prompt_dist="exact", seed=0)
    lens = {int(r.tokens.shape[0]) for r in reqs}
    assert all(8 <= n <= 24 for n in lens)
    assert lens - {8, 24}, "exact draw never left the bucket set"
    with pytest.raises(AssertionError):
        poisson_load(1, rate=1.0, vocab=64, prompt_dist="nope")
