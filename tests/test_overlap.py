"""Overlapped gossip pipeline (DESIGN §6): double-buffered payload slots,
issue/complete phase split, delayed-mixing semantics, checkpoint resume.

* ``overlap="off"`` is bit-identical to the synchronous packed-bus step
  (fused and unfused) — threading the flag changed nothing;
* ``overlap="delayed"`` step 0 equals the synchronous step exactly
  (W x(0) = x(0) at a replicated init) and the full trainer matches a
  hand-rolled delayed-EDM reference;
* the phase-split overlap mixer equals the synchronous schedule mixer
  payload-for-payload on the real ppermute engine (8-device subprocess),
  and a delayed ppermute train step still compiles to exactly one
  collective-permute per nonzero gossip term;
* pipeline checkpoints (live slot + parity) round-trip: a resumed run
  reproduces the uninterrupted trajectory;
* bus-path metrics (one fused reduction) equal the per-leaf reductions;
* the ring-DMA transport is only selected on a real TPU — ``ring_plan``
  extraction and the CPU fallback are pinned here.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.core import bus, metrics, ring
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train import (build_train_step, checkpoint, init_state,
                         make_gossip_schedule, state_specs, use_overlap)

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}

A = 4


def _model():
    cfg = ModelConfig(name="ov-tiny", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")
    return build_model(cfg)


def _batch(model):
    return SyntheticLM(vocab_size=model.cfg.vocab_size, seq_len=8,
                       n_agents=A).sample(jax.random.PRNGKey(1), 1)


def _run(overlap="off", **kw):
    return RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.1,
                     gossip_engine="dense", packed_bus=True,
                     overlap=overlap, remat=False, **kw)


def _steps(model, batch, run, n, fused=False, key=0):
    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(key))
    step = jax.jit(build_train_step(model, run, sched,
                                    use_fused_kernel=fused))
    traj = []
    for _ in range(n):
        state, m = step(state, batch)
        traj.append(m)
    return state, traj


# ---------------------------------------------------------------------------
# config resolution + pipeline slot mechanics
# ---------------------------------------------------------------------------

def test_overlap_resolution():
    assert not use_overlap(RunConfig())
    assert not use_overlap(RunConfig(overlap="off"))
    assert use_overlap(RunConfig(algorithm="edm", packed_bus=True,
                                 overlap="delayed"))
    # auto-bus production combo resolves too
    assert use_overlap(RunConfig(algorithm="edm", gossip_engine="ppermute",
                                 overlap="delayed"))
    with pytest.raises(AssertionError):   # needs the packed bus
        use_overlap(RunConfig(algorithm="edm", gossip_engine="shifts",
                              overlap="delayed"))
    with pytest.raises(AssertionError):   # gossip_every must be 1
        use_overlap(RunConfig(algorithm="edm", packed_bus=True,
                              overlap="delayed", gossip_every=2))
    with pytest.raises(AssertionError):   # f32 wire only
        use_overlap(RunConfig(algorithm="edm", packed_bus=True,
                              overlap="delayed", gossip_dtype="bfloat16"))
    with pytest.raises(AssertionError):   # unknown mode
        use_overlap(RunConfig(algorithm="edm", packed_bus=True,
                              overlap="async"))


def test_pipeline_slot_semantics():
    b0 = jnp.arange(2 * 16 * 128, dtype=jnp.float32).reshape(2, 16, 128)
    pipe = bus.make_pipeline(b0)
    assert pipe["slot"].shape == (2, 2, 16, 128)
    assert int(pipe["parity"]) == 0
    np.testing.assert_array_equal(np.asarray(bus.pipeline_payload(pipe)),
                                  np.asarray(b0))
    # advance writes the spare slot and flips the bit; the old live slot's
    # contents stay where they were (the double buffer)
    b1 = b0 + 1.0
    pipe2 = bus.pipeline_advance(pipe, b1)
    assert int(pipe2["parity"]) == 1
    np.testing.assert_array_equal(np.asarray(bus.pipeline_payload(pipe2)),
                                  np.asarray(b1))
    np.testing.assert_array_equal(np.asarray(pipe2["slot"][0]),
                                  np.asarray(b0))
    pipe3 = bus.pipeline_advance(pipe2, b0 + 2.0)
    assert int(pipe3["parity"]) == 0
    np.testing.assert_array_equal(np.asarray(bus.pipeline_payload(pipe3)),
                                  np.asarray(b0 + 2.0))
    # the mechanics are jit-clean
    jpipe = jax.jit(lambda p, x: bus.pipeline_advance(p, x))(pipe, b1)
    np.testing.assert_array_equal(np.asarray(bus.pipeline_payload(jpipe)),
                                  np.asarray(b1))


# ---------------------------------------------------------------------------
# fused bus metrics == per-leaf reductions
# ---------------------------------------------------------------------------

def test_bus_metrics_match_tree():
    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (A, 17, 9)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (A, 131)),
    }
    layout = bus.make_layout(tree, block_rows=8)
    packed = bus.pack_tree(layout, tree)
    want_norm = jnp.sqrt(sum(jnp.sum(jnp.square(l))
                             for l in jax.tree.leaves(tree)))
    np.testing.assert_allclose(float(metrics.bus_grad_norm(packed)),
                               float(want_norm), rtol=1e-6)
    np.testing.assert_allclose(float(metrics.bus_consensus(packed)),
                               float(metrics.consensus_distance(tree)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# overlap="off" is bit-identical to the plain packed-bus step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, True], ids=["unfused", "fused"])
def test_overlap_off_bit_identical(fused):
    model = _model()
    batch = _batch(model)
    s_def, t_def = _steps(model, batch, _run(), 8, fused=fused)
    s_off, t_off = _steps(model, batch, _run(overlap="off"), 8, fused=fused)
    for md, mo in zip(t_def, t_off):
        assert float(md["loss"]) == float(mo["loss"])
    np.testing.assert_array_equal(np.asarray(s_def["params"]),
                                  np.asarray(s_off["params"]))
    np.testing.assert_array_equal(np.asarray(s_def["opt"]["psi"]),
                                  np.asarray(s_off["opt"]["psi"]))


# ---------------------------------------------------------------------------
# delayed == hand-rolled one-step-stale-mixing reference
# ---------------------------------------------------------------------------

def test_delayed_matches_reference():
    model = _model()
    batch = _batch(model)
    run = _run(overlap="delayed")
    alpha, beta = run.alpha, run.beta
    state, traj = _steps(model, batch, run, 4)

    # reference: explicit delayed recursion on the logical tree with the
    # dense oracle W — grads at φ(t), combine of the in-flight φ(t), then
    # the local EDM update on the mixed iterate.
    from repro.core import make_mixer
    from repro.train import make_topology
    topo = make_topology(run, A)
    mix = make_mixer(topo, "dense")
    grad_fn = jax.vmap(jax.value_and_grad(
        lambda p, b: model.loss(p, b, remat=False, remat_policy="full")))
    params1 = model.init(jax.random.PRNGKey(0))
    phi = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (A,) + l.shape), params1)
    m_st = jax.tree.map(jnp.zeros_like, phi)
    psi = phi
    ref_losses = []
    for _ in range(4):
        x = mix(phi)
        losses, g = grad_fn(phi, batch)
        ref_losses.append(float(jnp.mean(losses)))
        m_st = jax.tree.map(lambda m, gg: beta * m + (1 - beta) * gg, m_st, g)
        psi_new = jax.tree.map(lambda xx, mm: xx - alpha * mm, x, m_st)
        phi = jax.tree.map(lambda pn, xx, ps: pn + xx - ps, psi_new, x, psi)
        psi = psi_new

    np.testing.assert_allclose([float(m["loss"]) for m in traj], ref_losses,
                               rtol=1e-5, atol=1e-6)
    from repro.train import bus_layout_for
    layout = bus_layout_for(model, A)
    got_phi = bus.unpack_tree(layout, bus.pipeline_payload(state["pipeline"]))
    for w, g in zip(jax.tree.leaves(phi), jax.tree.leaves(got_phi)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)
    # params is the mixed iterate x(t) = W φ(t) of the last step
    got_x = bus.unpack_tree(layout, state["params"])
    for w, g in zip(jax.tree.leaves(x), jax.tree.leaves(got_x)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_delayed_step0_and_envelope():
    """Step 0 of the delayed pipeline equals the synchronous step exactly;
    later losses stay in the synchronous [loss(t+1), loss(t)] envelope
    (the pre-mix iterate sits between x(t) and x(t+1))."""
    model = _model()
    batch = _batch(model)
    _, t_off = _steps(model, batch, _run(), 9)
    _, t_del = _steps(model, batch, _run(overlap="delayed"), 8)
    lo = [float(m["loss"]) for m in t_off]
    ld = [float(m["loss"]) for m in t_del]
    assert abs(lo[0] - ld[0]) < 1e-6
    for t in range(8):
        lo_t, hi_t = sorted((lo[t], lo[t + 1]))
        tol = 0.05 * abs(lo[t])
        assert lo_t - tol <= ld[t] <= hi_t + tol, (t, ld[t], lo_t, hi_t)


# ---------------------------------------------------------------------------
# straggler degradation (DESIGN §8): late slots fall back to self-weight
# ---------------------------------------------------------------------------

def test_straggler_matches_self_weight_oracle():
    """A forced-late gossip term degrades the delayed step to the
    self-weight matrix W_eff = Σ_{k∉late} w_k P_k + (Σ_{k∈late} w_k) I:
    the trainer trajectory equals a hand-rolled delayed-EDM reference with
    the per-step W_eff, never NaNs, and steps without late slots (incl.
    step 0) match the plain delayed run exactly."""
    from repro.core import StragglerPlan
    from repro.train import make_topology

    model = _model()
    batch = _batch(model)
    run = _run(overlap="delayed")
    alpha, beta = run.alpha, run.beta
    topo = make_topology(run, A)               # ring(4): K = 3 terms
    K = len(topo.terms)
    late_by_step = {2: (1,), 3: (1, 2)}
    plan = StragglerPlan(n_terms=K, late=tuple(
        (s, ks) for s, ks in late_by_step.items()))

    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, sched, straggler_plan=plan))
    traj = []
    for _ in range(6):
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"])), "straggler step NaNed"
        traj.append(float(m["loss"]))

    # reference: the delayed recursion of test_delayed_matches_reference
    # with an explicit per-step W_eff oracle
    n = A
    idx = np.arange(n)

    def W_eff(late_ks):
        W = np.zeros((n, n), np.float32)
        for k, t in enumerate(topo.terms):
            src = idx if k in late_ks else topo.term_sources(t)
            W[idx, src] += t.weight
        np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-6)
        return jnp.asarray(W)

    grad_fn = jax.vmap(jax.value_and_grad(
        lambda p, b: model.loss(p, b, remat=False, remat_policy="full")))
    params1 = model.init(jax.random.PRNGKey(0))
    phi = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (A,) + l.shape), params1)
    m_st = jax.tree.map(jnp.zeros_like, phi)
    psi = phi
    ref_losses = []
    for t in range(6):
        W = W_eff(late_by_step.get(t, ()))
        x = jax.tree.map(lambda l: jnp.einsum("ij,j...->i...", W, l), phi)
        losses, g = grad_fn(phi, batch)
        ref_losses.append(float(jnp.mean(losses)))
        m_st = jax.tree.map(lambda m_, g_: beta * m_ + (1 - beta) * g_,
                            m_st, g)
        psi_new = jax.tree.map(lambda xx, mm: xx - alpha * mm, x, m_st)
        phi = jax.tree.map(lambda pn, xx, ps: pn + xx - ps, psi_new, x, psi)
        psi = psi_new
    np.testing.assert_allclose(traj, ref_losses, rtol=1e-5, atol=1e-6)

    # late-free prefix == the plain delayed run (step 0 synchronous)
    _, t_plain = _steps(model, batch, run, 2)
    for t in range(2):
        np.testing.assert_allclose(traj[t], float(t_plain[t]["loss"]),
                                   rtol=1e-6, atol=1e-7)


def test_straggler_plan_arity_and_mode_guards():
    """straggler_plan needs overlap='delayed' and the mixer's stack arity."""
    from repro.core import StragglerPlan

    model = _model()
    plan = StragglerPlan(n_terms=3)
    with pytest.raises(AssertionError):
        build_train_step(model, _run(), make_gossip_schedule(_run(), A),
                         straggler_plan=plan)
    run = _run(overlap="delayed")
    with pytest.raises(AssertionError):
        build_train_step(model, run, make_gossip_schedule(run, A),
                         straggler_plan=StragglerPlan(n_terms=5))


# ---------------------------------------------------------------------------
# checkpoint: pipeline state (parity + live slot) round-trips (satellite)
# ---------------------------------------------------------------------------

def test_checkpoint_pipeline_roundtrip(tmp_path):
    """Resume at step t reproduces the uninterrupted delayed trajectory —
    including an ODD parity checkpoint (live payload in slot 1)."""
    from repro.train import bus_layout_for

    model = _model()
    batch = _batch(model)
    run = _run(overlap="delayed")
    layout = bus_layout_for(model, A)
    sched = make_gossip_schedule(run, A)
    step = jax.jit(build_train_step(model, run, sched))

    state = init_state(model, run, A, jax.random.PRNGKey(0))
    mids = []
    for t in range(6):
        if t == 3:
            mids.append(jax.tree.map(lambda x: np.asarray(x), state))
        state, m = step(state, batch)
    assert int(mids[0]["pipeline"]["parity"]) == 1  # odd-parity snapshot

    path = str(tmp_path / "pipe.npz")
    checkpoint.save_state(path, mids[0], layout=layout)
    like = init_state(model, run, A, jax.random.PRNGKey(0))
    restored = checkpoint.load_state(path, like, layout=layout)
    assert int(restored["step"]) == 3
    np.testing.assert_allclose(
        np.asarray(bus.pipeline_payload(restored["pipeline"])),
        np.asarray(bus.pipeline_payload(
            {k: jnp.asarray(v) for k, v in mids[0]["pipeline"].items()})),
        rtol=0, atol=0)

    resumed = restored
    for _ in range(3):
        resumed, mr = step(resumed, batch)
    np.testing.assert_allclose(np.asarray(resumed["params"]),
                               np.asarray(state["params"]),
                               rtol=0, atol=0)
    np.testing.assert_allclose(
        np.asarray(bus.pipeline_payload(resumed["pipeline"])),
        np.asarray(bus.pipeline_payload(state["pipeline"])),
        rtol=0, atol=0)


def test_full_state_checkpoint_without_pipeline(tmp_path):
    """save_state/load_state also round-trip a synchronous bus state (no
    pipeline key) and keep the on-disk format logical."""
    from repro.train import bus_layout_for

    model = _model()
    batch = _batch(model)
    run = _run()
    layout = bus_layout_for(model, A)
    sched = make_gossip_schedule(run, A)
    step = jax.jit(build_train_step(model, run, sched))
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    for _ in range(2):
        state, _ = step(state, batch)
    path = str(tmp_path / "sync.npz")
    checkpoint.save_state(path, state, layout=layout)
    like = init_state(model, run, A, jax.random.PRNGKey(0))
    restored = checkpoint.load_state(path, like, layout=layout)
    np.testing.assert_array_equal(np.asarray(restored["params"]),
                                  np.asarray(state["params"]))
    assert int(restored["step"]) == 2


def test_state_specs_pipeline():
    from jax.sharding import PartitionSpec as P

    model = _model()
    run = _run(overlap="delayed")
    state = jax.eval_shape(
        lambda: init_state(model, run, A, jax.random.PRNGKey(0)))
    specs = state_specs(model, run, multi_pod=False)
    jax.tree.map(lambda sds, sp: None, state, specs,
                 is_leaf=lambda x: isinstance(x, P))
    assert specs["pipeline"]["slot"] == P(None, "data")
    assert specs["pipeline"]["parity"] == P()
    assert state["pipeline"]["slot"].ndim == 4


# ---------------------------------------------------------------------------
# ring-DMA transport: plan extraction + CPU fallback
# ---------------------------------------------------------------------------

def test_ring_plan_and_fallback():
    from repro.core import exp_graph, hierarchical
    from repro.kernels import ring_dma

    topo = ring(8)
    plan = ring_dma.ring_plan(topo)
    assert plan is not None
    w_c, w_l, w_r = plan
    # weights must re-assemble the topology's terms exactly
    np.testing.assert_allclose(w_c + w_l + w_r, 1.0, rtol=1e-6)
    W = topo.dense_matrix()
    np.testing.assert_allclose(w_c, W[0, 0], rtol=1e-6)
    np.testing.assert_allclose(w_l, W[1, 0], rtol=1e-6)   # from-left edge
    np.testing.assert_allclose(w_r, W[0, 1], rtol=1e-6)   # from-right edge
    assert ring_dma.ring_plan(exp_graph(8)) is None
    assert ring_dma.ring_plan(hierarchical(2, 4)) is None
    # ring(2): ±1 coincide (shift 1 ≡ −1 mod 2) — still a valid plan
    assert ring_dma.ring_plan(ring(2)) is not None

    # off-TPU the transport is never supported → ppermute fallback
    assert not ring_dma.on_tpu()
    assert not ring_dma.ring_dma_supported(topo)
    assert ring_dma.ring_dma_supported(topo, backend="tpu")
    assert not ring_dma.ring_dma_supported(topo, n_axes=2, backend="tpu")
    assert not ring_dma.ring_dma_supported(topo, B=4, backend="tpu")
    assert not ring_dma.ring_dma_supported(exp_graph(8), backend="tpu")


def test_ring_dma_transport_forced_asserts_off_tpu():
    """transport='ring_dma' must refuse to silently fall back."""
    from repro.core import make_mixer
    from repro.launch.mesh import make_sim_mesh

    mesh = make_sim_mesh()
    mix = make_mixer(ring(1), "ppermute", mesh=mesh, agent_axes="data",
                     transport="ring_dma")
    with pytest.raises(AssertionError):
        mix({"w": jnp.ones((1, 8, 128))})


# ---------------------------------------------------------------------------
# ppermute engine: overlap mixer == schedule mixer + HLO permute count
# (8-device subprocess)
# ---------------------------------------------------------------------------

_PPERMUTE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ModelConfig, RunConfig
from repro.core import (RoundRobinExp, StaticSchedule, make_overlap_mixer,
                        make_schedule_mixer, ring, exp_graph)
from repro.data import SyntheticLM
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
from repro.models import build_model
from repro.train import build_train_step, init_state, make_gossip_schedule

A = 8
mesh = make_gossip_mesh(A)
axes = gossip_agent_axes(mesh)
x = jax.device_put(jax.random.normal(jax.random.PRNGKey(0), (A, 64, 128)),
                   NamedSharding(mesh, P(axes)))
for sched in (StaticSchedule(ring(A)), StaticSchedule(exp_graph(A)),
              RoundRobinExp(A)):
    for fused in (False, True):
        mix = make_schedule_mixer(sched, "ppermute", mesh=mesh,
                                  agent_axes=axes, use_fused_kernel=fused)
        issue, complete = make_overlap_mixer(sched, "ppermute", mesh=mesh,
                                             agent_axes=axes,
                                             use_fused_kernel=fused)
        f = jax.jit(lambda x, s: complete(issue(x, s), s))
        for s in range(sched.period):
            np.testing.assert_allclose(
                np.asarray(f(x, s)), np.asarray(mix(x, step=s)),
                rtol=1e-6, atol=1e-6,
                err_msg=f"{sched.name} fused={fused} step={s}")
print("OVERLAP_MIXER_OK")

cfg = ModelConfig(name="ov-pp", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32")
model = build_model(cfg)
batch = SyntheticLM(vocab_size=64, seq_len=8, n_agents=A).sample(
    jax.random.PRNGKey(1), 1)

def build(overlap):
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.05,
                    gossip_engine="ppermute", packed_bus=True,
                    overlap=overlap, remat=False)
    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, sched, mesh=mesh,
                                    agent_axes=axes, use_fused_kernel=True),
                   donate_argnums=(0,))
    return state, step

state, step = build("delayed")
hlo = step.lower(state, batch).compile().as_text()
n_perm = hlo.count("collective-permute(")
assert n_perm == 2, ("delayed ring step must keep 1 permute/term", n_perm)
print("OVERLAP_HLO_OK")

s_d, step_d = build("delayed")
s_o, step_o = build("off")
ld, lo = [], []
for t in range(9):
    s_o, mo = step_o(s_o, batch); lo.append(float(mo["loss"]))
    if t < 8:
        s_d, md = step_d(s_d, batch); ld.append(float(md["loss"]))
assert abs(ld[0] - lo[0]) < 1e-6
for t in range(8):
    lo_t, hi_t = sorted((lo[t], lo[t + 1]))
    tol = 0.05 * abs(lo[t])
    assert lo_t - tol <= ld[t] <= hi_t + tol, (t, ld[t], lo_t, hi_t)
print("OVERLAP_PPERMUTE_OK")
"""


def test_overlap_ppermute_subprocess():
    r = subprocess.run([sys.executable, "-c", _PPERMUTE_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for marker in ("OVERLAP_MIXER_OK", "OVERLAP_HLO_OK",
                   "OVERLAP_PPERMUTE_OK"):
        assert marker in r.stdout, marker


# ---------------------------------------------------------------------------
# benchmarks: autotune + divergence gates smoke (subprocess, repo cwd)
# ---------------------------------------------------------------------------

def test_autotune_and_divergence_gates_smoke():
    code = (
        "from benchmarks.gossip_micro import autotune_block_rows, "
        "overlap_divergence_gates\n"
        "rows = autotune_block_rows(candidates=(128, 256), "
        "rows_sizes=(256,), iters=2, verbose=False)\n"
        "assert rows[0]['edm_update']['best'] in (128, 256)\n"
        "assert rows[0]['gossip_axpy']['best'] in (128, 256)\n"
        "gates = overlap_divergence_gates(verbose=False)\n"
        "assert gates['quadratic']['ratio'] <= 2.0\n"
        "assert gates['logistic']['ratio'] <= 1.05\n"
        "print('GATES_OK')\n"
    )
    r = subprocess.run([sys.executable, "-c", code], cwd=REPO, env=ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "GATES_OK" in r.stdout
