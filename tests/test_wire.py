"""Quantized gossip wire (DESIGN §9): codec round trips, error-feedback
equivalence, and the wire-dtype acceptance criteria.

* codec round-trip properties — per-block int8 error bound under worst-case
  dynamic range, bf16 relative bound, pad-zero exactness, NaN/Inf guards
  (parametrized always; property-based under hypothesis when installed);
* encode/decode == the dense reference oracle on all three formats ×
  {B = 1, B = 4} × {fused, unfused}, plus a liveness-masked round
  (subprocess on a forced multi-device host platform);
* bus-resident EF trajectory == the per-leaf ``edm_ef`` optimizer (the
  registered bf16 error-feedback algorithm) — one recursion, two layouts;
* HLO acceptance: the full train step's collective-permute operands carry
  the WIRE dtype (bf16 / s8 + small f32 scale sidecars), including the
  ``overlap="delayed"`` and ``agents="pod"`` compositions;
* checkpoint round-trip of the bus-shaped residual across wire formats,
  and the f32 → compressed resume zero-fill;
* ``use_wire`` resolution + the modeled byte cuts (≥2× bf16, ≥3.5× int8).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.core import bus, make_edm_bus_ef, make_mixer, make_optimizer, ring
from repro.core.wire import WIRE_FORMATS, encode_ef, make_codec

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


# ---------------------------------------------------------------------------
# codec round trips
# ---------------------------------------------------------------------------

def _bus_like(shape, key=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     jnp.float32)


def test_f32_codec_is_identity():
    c = make_codec("f32", 8)
    x = _bus_like((3, 16, 128))
    pay = c.encode(x)
    np.testing.assert_array_equal(np.asarray(c.decode(pay)), np.asarray(x))
    assert c.payload_bytes(1000) == 4000 and c.compression_ratio(1000) == 1.0


def test_bf16_codec_relative_bound():
    c = make_codec("bf16", 8)
    x = _bus_like((2, 24, 128), scale=100.0)
    pay = c.encode(x)
    assert pay.dtype == jnp.bfloat16
    err = np.abs(np.asarray(c.decode(pay)) - np.asarray(x))
    # bf16 has an 8-bit mantissa: relative error <= 2^-8
    assert np.all(err <= np.abs(np.asarray(x)) * 2.0 ** -8 + 1e-30)


def test_int8_per_block_scale_worst_case_dynamic_range():
    """One huge block must not destroy a tiny neighbour: the scale is
    per-(block_rows x 128) block, so each block sees its own absmax and the
    elementwise error is bounded by scale/2 = absmax_block / 254."""
    br = 8
    c = make_codec("int8", br)
    huge = _bus_like((1, br, 128), key=1, scale=1e6)
    tiny = _bus_like((1, br, 128), key=2, scale=1e-6)
    x = jnp.concatenate([huge, tiny], axis=1)          # (1, 2*br, 128)
    q, s = c.encode(x)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.shape == (1, 2)                            # one scale per block
    dec = np.asarray(c.decode((q, s)))
    xb = np.asarray(x).reshape(2, br * 128)
    db = dec.reshape(2, br * 128)
    for b in range(2):
        bound = np.abs(xb[b]).max() / 254.0 + 1e-30
        assert np.abs(db[b] - xb[b]).max() <= bound * 1.01, b


def test_int8_pad_zero_and_nonfinite_guard():
    c = make_codec("int8", 8)
    # all-zero block -> scale 0 and exact-zero decode, no 0/0 NaN
    q, s = c.encode(jnp.zeros((2, 16, 128)))
    assert not np.any(np.isnan(np.asarray(s)))
    assert np.all(np.asarray(c.decode((q, s))) == 0.0)
    # zeros INSIDE a nonzero block still decode to exact zero (round(0) = 0)
    x = _bus_like((1, 8, 128)).at[0, 0, :].set(0.0)
    dec = np.asarray(c.decode(c.encode(x)))
    assert np.all(dec[0, 0, :] == 0.0)
    # NaN quantizes to 0, Inf saturates, and neither poisons the block scale
    x = _bus_like((1, 8, 128))
    bad = x.at[0, 0, 0].set(jnp.nan).at[0, 0, 1].set(jnp.inf) \
           .at[0, 0, 2].set(-jnp.inf)
    dq = np.asarray(c.decode(c.encode(bad)))
    assert np.all(np.isfinite(dq))
    ref = np.asarray(c.decode(c.encode(x)))
    np.testing.assert_allclose(dq[0, 1:], ref[0, 1:], rtol=1e-6)


@pytest.mark.parametrize("fmt", ["f32", "bf16", "int8"])
def test_encode_ef_reconstructs(fmt):
    """decode(payload) + residual == the pre-quantization correction, and
    the f32 format carries a structurally-real zero residual."""
    c = make_codec(fmt, 8)
    x = _bus_like((2, 32, 128), scale=7.0)
    pay, e = encode_ef(c, x)
    np.testing.assert_allclose(np.asarray(c.decode(pay) + e), np.asarray(x),
                               rtol=1e-6, atol=1e-6)
    if fmt == "f32":
        assert np.all(np.asarray(e) == 0.0)


def test_payload_bytes_model():
    n = 512 * 128                                       # one bus agent
    assert make_codec("bf16", 8).payload_bytes(n) == 2 * n
    got = make_codec("int8", 8).payload_bytes(n)
    assert got == n + 4 * (n // (8 * 128))              # q + f32 scale/block
    assert make_codec("bf16", 8).compression_ratio(n) == 2.0
    assert make_codec("int8", 8).compression_ratio(n) >= 3.5


# ---------------------------------------------------------------------------
# property-based round trip (hypothesis, optional)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - optional extra
    HAVE_HYP = False


if HAVE_HYP:
    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(fmt=st.sampled_from(["bf16", "int8"]),
           batch=st.integers(1, 4), nblocks=st.integers(1, 5),
           log_scale=st.floats(-20, 20), seed=st.integers(0, 2 ** 16))
    def test_codec_roundtrip_property(fmt, batch, nblocks, log_scale, seed):
        """Per-block error bound holds at any block count / dynamic range:
        int8 error <= absmax_block/254 per element, bf16 <= 2^-8 relative."""
        br = 8
        c = make_codec(fmt, br)
        x = _bus_like((batch, nblocks * br, 128), key=seed,
                      scale=float(10.0 ** (log_scale / 10.0)))
        dec = np.asarray(c.decode(c.encode(x)))
        xn = np.asarray(x)
        if fmt == "bf16":
            assert np.all(np.abs(dec - xn) <= np.abs(xn) * 2.0 ** -8 + 1e-37)
        else:
            xb = xn.reshape(batch, nblocks, br * 128)
            db = dec.reshape(batch, nblocks, br * 128)
            bound = np.abs(xb).max(-1, keepdims=True) / 254.0 * 1.01 + 1e-37
            assert np.all(np.abs(db - xb) <= bound)
        # EF identity under the same draw
        pay, e = encode_ef(c, x)
        np.testing.assert_allclose(np.asarray(c.decode(pay) + e), xn,
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# bus-resident EF == per-leaf edm_ef (the registered algorithm), bf16 wire
# ---------------------------------------------------------------------------

def _ragged_tree(A, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    return {
        "emb": jax.random.normal(ks[0], (A, 17, 9)),
        "w": jax.random.normal(ks[1], (A, 33)),
        "b": jax.random.normal(ks[2], (A, 2, 3, 5)),
        "head": jax.random.normal(ks[3], (A, 129)),
    }


def test_bus_ef_matches_leafwise_edm_ef():
    """The bus-resident bf16 EF step IS the per-leaf ``edm_ef`` recursion:
    pack is an exact f32 relayout and the bf16 round trip is elementwise,
    so x AND the carried residual agree leaf-for-leaf across layouts."""
    A = 8
    topo = ring(A)
    tree = _ragged_tree(A)
    grads = jax.tree.map(lambda x: 0.1 * x, tree)
    mix = make_mixer(topo, "dense")

    opt = make_optimizer("edm_ef", alpha=0.05, beta=0.9, mix=mix)
    x, st = tree, opt.init(tree)
    for _ in range(5):
        x, st = opt.step(x, grads, st)

    layout = bus.make_layout(tree, block_rows=8)
    codec = make_codec("bf16", layout.block_rows)
    bmix = make_mixer(topo, "dense", wire=codec)
    bopt = make_edm_bus_ef(0.05, 0.9, bmix, codec,
                           block_rows=layout.block_rows)
    xb = bus.pack_tree(layout, tree)
    stb = bopt.init(xb)
    gb = bus.pack_tree(layout, grads)
    for _ in range(5):
        xb, stb = bopt.step(xb, gb, stb)

    for got, want in zip(jax.tree.leaves(bus.unpack_tree(layout, xb)),
                         jax.tree.leaves(x)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
    for got, want in zip(
            jax.tree.leaves(bus.unpack_tree(layout, stb["e"])),
            jax.tree.leaves(st["e"])):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("fmt", ["bf16", "int8"])
def test_naive_quantization_leaves_residual_zero(fmt):
    """The ``error_feedback=False`` negative control really is naive: the
    residual never moves, and its payload differs from the EF payload."""
    A = 4
    topo = ring(A)
    codec = make_codec(fmt, 8)
    mix = make_mixer(topo, "dense", wire=codec)
    x0 = _bus_like((A, 16, 128), key=3)
    g = 0.1 * x0
    ef = make_edm_bus_ef(0.05, 0.9, mix, codec, block_rows=8)
    naive = make_edm_bus_ef(0.05, 0.9, mix, codec, block_rows=8,
                            error_feedback=False)
    xe, ste = x0, ef.init(x0)
    xn, stn = x0, naive.init(x0)
    for _ in range(3):
        xe, ste = ef.step(xe, g, ste)
        xn, stn = naive.step(xn, g, stn)
    assert np.all(np.asarray(stn["e"]) == 0.0)
    assert np.any(np.asarray(ste["e"]) != 0.0)
    assert not np.allclose(np.asarray(xe), np.asarray(xn))


# ---------------------------------------------------------------------------
# wire-coded ppermute engine == dense oracle on quantize(x)  (subprocess)
# ---------------------------------------------------------------------------

_WIRE_MATRIX_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import degrade_round, make_mixer, mix_dense, ring
from repro.core.wire import make_codec
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh

rows, br = 64, 8
for fmt in ("f32", "bf16", "int8"):
    codec = make_codec(fmt, br)
    for B in (1, 4):
        A = 8 * B
        topo = ring(A)
        mesh = make_gossip_mesh(A, agents_per_device=B)
        axes = gossip_agent_axes(mesh)
        x = 3.0 * jax.random.normal(jax.random.PRNGKey(0), (A, rows, 128),
                                    jnp.float32)
        want = mix_dense(topo, codec.quantize(x))
        for fused in (False, True):
            mix = make_mixer(topo, "ppermute", mesh, axes,
                             use_fused_kernel=fused, wire=codec)
            got = mix(codec.encode(x))
            assert got.dtype == jnp.float32, (fmt, got.dtype)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5,
                err_msg=f"{fmt}/B={B}/fused={fused}")
        print(f"WIRE_AGREE {fmt}/B={B}")

# liveness-masked round (per-agent weight columns ride the wire path)
codec = make_codec("int8", br)
masked = degrade_round(ring(8), [a not in (3,) for a in range(8)])
x = jax.random.normal(jax.random.PRNGKey(1), (8, rows, 128), jnp.float32)
want = mix_dense(masked, codec.quantize(x))
mesh = make_gossip_mesh(8)
axes = gossip_agent_axes(mesh)
for fused in (False, True):
    mix = make_mixer(masked, "ppermute", mesh, axes,
                     use_fused_kernel=fused, wire=codec)
    np.testing.assert_allclose(np.asarray(mix(codec.encode(x))),
                               np.asarray(want), rtol=1e-5, atol=1e-5,
                               err_msg=f"masked fused={fused}")
print("WIRE_MASKED_AGREE")
print("WIRE_MATRIX_OK")
"""


def test_wire_engine_matches_dense_oracle():
    """Acceptance: permutes commute with the elementwise decode, so the
    wire-coded ppermute engine equals the f32 dense oracle applied to
    ``codec.quantize(x)`` exactly — all formats x {B=1, B=4} x
    {fused, unfused}, plus a degraded (masked) round."""
    r = subprocess.run([sys.executable, "-c", _WIRE_MATRIX_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "WIRE_MATRIX_OK" in r.stdout
    assert "WIRE_MASKED_AGREE" in r.stdout


# ---------------------------------------------------------------------------
# HLO: the train step's permute operands carry the wire dtype  (subprocess)
# ---------------------------------------------------------------------------

_WIRE_HLO_CODE = """
import os, re
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.configs.base import ModelConfig, RunConfig
from repro.data import SyntheticLM
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
from repro.models import build_model
from repro.train import build_train_step, init_state, make_gossip_schedule

cfg = ModelConfig(name="wire-hlo", family="dense", n_layers=1,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=64, dtype="float32")
model = build_model(cfg)

def permute_types(hlo):
    pat = re.compile(r"= ([a-z0-9]+)\\[([0-9,]*)\\]\\S* collective-permute\\(")
    out = []
    for m in pat.finditer(hlo):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), int(np.prod(dims)) if dims else 1))
    return out

def stablehlo_permute_types(txt):
    pat = re.compile(r'stablehlo\\.collective_permute"[^\\n]*'
                     r'\\(tensor<(?:[0-9]+x)*([a-z0-9]+)>\\)')
    return pat.findall(txt)

def step_lowered(wire, overlap="off", pod=False):
    A, shards = (2, 4) if pod else (8, 1)
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.2,
                    agents="pod" if pod else "data",
                    gossip_engine="ppermute", packed_bus=True,
                    overlap=overlap, wire=wire, remat=False)
    sched = make_gossip_schedule(run, A)
    if pod:
        mesh = make_gossip_mesh(A, pods=A, shards=shards)
        axes = gossip_agent_axes(mesh, sharded=True)
        shard_axes = "data"
    else:
        mesh = make_gossip_mesh(A)
        axes = gossip_agent_axes(mesh)
        shard_axes = None
    state = init_state(model, run, A, jax.random.PRNGKey(0), shards=shards)
    batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8,
                        n_agents=A).sample(jax.random.PRNGKey(1), 1)
    step = build_train_step(model, run, sched, mesh=mesh, agent_axes=axes,
                            shard_axes=shard_axes)
    return jax.jit(step).lower(state, batch)

for overlap in ("off", "delayed"):
    # bf16 is pinned at the StableHLO level: the program REQUESTS bf16
    # permutes; XLA's CPU float-normalization legalizes bf16 collectives
    # to f32 on this host backend (TPU ships them natively).
    dts = stablehlo_permute_types(step_lowered("bf16", overlap).as_text())
    assert dts and all(dt == "bf16" for dt in dts), (overlap, dts)
    print(f"SHLO_BF16 overlap={overlap}: {len(dts)} permutes, all bf16")

    # s8 is a legal CPU collective type -> pin the COMPILED module: the
    # wire really carries int8 end to end, plus tiny f32 scale sidecars.
    perms = permute_types(
        step_lowered("int8", overlap).compile().as_text())
    s8 = [n for dt, n in perms if dt == "s8"]
    rest = [(dt, n) for dt, n in perms if dt != "s8"]
    assert s8, (overlap, perms)
    assert all(dt == "f32" and n <= min(s8) // 128 for dt, n in rest), \\
        (overlap, perms)
    print(f"HLO_INT8 overlap={overlap}: {len(s8)} s8 + {len(rest)} scale")

# agents="pod": shard-resident compressed gossip (DESIGN 7 + 9)
perms = permute_types(step_lowered("int8", pod=True).compile().as_text())
s8 = [n for dt, n in perms if dt == "s8"]
assert s8 and all(dt in ("s8", "f32") for dt, _ in perms), perms
print(f"HLO_POD int8: {len(s8)} s8 permutes")
print("WIRE_HLO_OK")
"""


def test_train_step_permutes_carry_wire_dtype():
    """Acceptance: the FULL train step (incl. overlap='delayed' and
    agents='pod') lowers to collective-permutes whose operands are the
    wire dtype — bf16 buses (StableHLO pin; XLA CPU's float
    normalization re-widens bf16 collectives on this backend), or s8
    buses + per-block f32 scale sidecars (compiled-HLO pin); no
    full-size f32 payload survives on the wire."""
    r = subprocess.run([sys.executable, "-c", _WIRE_HLO_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "WIRE_HLO_OK" in r.stdout


# ---------------------------------------------------------------------------
# checkpointing the residual + use_wire resolution
# ---------------------------------------------------------------------------

def _tiny_state(wire):
    from repro.models import build_model
    from repro.train import bus_layout_for, init_state

    cfg = ModelConfig(name="wire-tiny", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, dtype="float32")
    model = build_model(cfg)
    run = RunConfig(global_batch=4, seq_len=8, algorithm="edm",
                    packed_bus=True, wire=wire, remat=False)
    state = init_state(model, run, 4, jax.random.PRNGKey(0))
    return bus_layout_for(model, 4), state


@pytest.mark.parametrize("fmt", ["bf16", "int8"])
def test_checkpoint_residual_roundtrip(fmt, tmp_path):
    """The bus-shaped residual rides the layout-independent checkpoint
    machinery: save/load round-trips it exactly, and an f32-wire
    checkpoint (no residual on disk) resumes into a compressed run with
    the EF-correct zero fill."""
    from repro.train import checkpoint

    layout, state = _tiny_state(fmt)
    assert "e" in state["opt"] and state["opt"]["e"].shape == \
        state["params"].shape
    # a realistic residual is pad-zero (the codec maps pad 0 -> 0); the
    # checkpoint stores the LOGICAL tree, so only pad-zero buses round-trip
    raw = _bus_like(state["opt"]["e"].shape, key=5)
    state["opt"]["e"] = bus.pack_tree(layout, bus.unpack_tree(layout, raw))
    p = str(tmp_path / f"wire_{fmt}.npz")
    checkpoint.save_state(p, state, layout=layout)
    _, fresh = _tiny_state(fmt)
    back = checkpoint.load_state(p, fresh, layout=layout)
    np.testing.assert_array_equal(np.asarray(back["opt"]["e"]),
                                  np.asarray(state["opt"]["e"]))
    np.testing.assert_array_equal(np.asarray(back["params"]),
                                  np.asarray(state["params"]))

    # compressed checkpoint -> f32 run: the stale residual is ignored
    _, f32_state = _tiny_state("f32")
    assert "e" not in f32_state["opt"]
    back = checkpoint.load_state(p, f32_state, layout=layout)
    assert "e" not in back["opt"]

    # f32 checkpoint -> compressed run: residual zero-fills
    layout, f32_state = _tiny_state("f32")
    p2 = str(tmp_path / "f32.npz")
    checkpoint.save_state(p2, f32_state, layout=layout)
    _, comp = _tiny_state(fmt)
    back = checkpoint.load_state(p2, comp, layout=layout)
    assert np.all(np.asarray(back["opt"]["e"]) == 0.0)
    np.testing.assert_array_equal(np.asarray(back["params"]),
                                  np.asarray(f32_state["params"]))


def test_use_wire_resolution():
    from repro.train import use_wire

    assert use_wire(RunConfig()) == "f32"
    assert use_wire(RunConfig(algorithm="edm", gossip_engine="ppermute",
                              wire="bf16")) == "bf16"
    assert use_wire(RunConfig(algorithm="edm", packed_bus=True,
                              wire="int8")) == "int8"
    with pytest.raises(AssertionError):        # needs the packed bus
        use_wire(RunConfig(algorithm="edm", gossip_engine="shifts",
                           wire="int8"))
    with pytest.raises(AssertionError):        # excludes the cast lever
        use_wire(RunConfig(algorithm="edm", gossip_engine="ppermute",
                           wire="int8", gossip_dtype="bfloat16"))


def test_wire_bytes_per_step_with_codec():
    """Modeled wire bytes derive from the codec: >= 2x (bf16) and >= 3.5x
    (int8 + scales) vs f32 at n = 32 with the permute row counts
    unchanged (the acceptance numbers BENCH_wire.json records)."""
    from repro.core.schedule import StaticSchedule, wire_bytes_per_step

    sched = StaticSchedule(ring(32))
    elems = 512 * 128
    kw = dict(elems_per_agent=elems, engine="ppermute")
    f32 = wire_bytes_per_step(sched, 0, **kw)
    assert f32 == wire_bytes_per_step(sched, 0, codec=make_codec("f32", 8),
                                      **kw)
    bf16 = wire_bytes_per_step(sched, 0, codec=make_codec("bf16", 8), **kw)
    int8 = wire_bytes_per_step(sched, 0, codec=make_codec("int8", 8), **kw)
    assert f32 / bf16 == 2.0
    assert f32 / int8 >= 3.5
