"""Elastic fault-tolerant gossip (DESIGN §8): liveness masks, drop plans,
degraded schedules, cross-size checkpoints, churn divergence gates.

* :func:`degrade_round` survivor-rank rewiring: doubly stochastic with a
  positive diagonal for ANY shipped base round, dead rows/cols exactly
  identity — and the degraded ring(8) → 6 survivors IS ring(6);
* :class:`DropPlan` JSON round trips, validation, deterministic random
  plans with never-dropped anchors;
* :class:`ElasticSchedule` satisfies the per-epoch Assumption-1 transfer
  for every base schedule family, concrete and traced ``round_index``;
* degraded ppermute == dense == sharded oracle over {static, round_robin}
  × {fused, unfused} × {B=1, B=4}, one collective-permute per nonzero
  survivor shift (HLO pin), straggler ``complete(late=)`` == the
  self-weight W_eff oracle and never reads the late (NaN) buffer
  (8-device subprocess);
* cross-size checkpoints: 8→8 round-trips bitwise, a shrink is bit-exact,
  joiners take the consensus mean with ψ := x, and an A=8 churn run
  resumed at A=6 reproduces the uninterrupted degraded trajectory exactly;
* the §E.1/§E.2 churn divergence gates (10 %-drop plan vs no-churn, same
  noise keys) hold — the raising gate behind ``gossip_micro --churn``.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DropPlan, ElasticSchedule, LivenessMask,
                        MaskedTopology, RoundRobinExp, StaticSchedule,
                        StragglerPlan, degrade_round, exp_graph,
                        hierarchical, ring, wire_bytes_per_step)
from repro.core.mixing import mix_dense, mix_shifts

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


# ---------------------------------------------------------------------------
# DropPlan: construction, validation, JSON wire format
# ---------------------------------------------------------------------------

def test_drop_plan_json_round_trip(tmp_path):
    plan = DropPlan.from_events(8, [(0, []), (8, [3, 5]), (16, [1])])
    spec = plan.to_json()
    assert DropPlan.from_json(spec) == plan                   # dict
    assert DropPlan.from_json(json.dumps(spec)) == plan       # inline JSON
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(spec))
    assert DropPlan.from_json(str(p)) == plan                 # file path
    # "alive" is accepted in place of "down"
    spec2 = {"n_agents": 4,
             "epochs": [{"start": 0, "alive": [True, True, False, True]}]}
    assert DropPlan.from_json(spec2).alive_at(0).tolist() == \
        [True, True, False, True]


def test_drop_plan_validation():
    with pytest.raises(AssertionError):   # first epoch must start at 0
        DropPlan.from_events(4, [(2, [])])
    with pytest.raises(AssertionError):   # strictly increasing starts
        DropPlan.from_events(4, [(0, []), (4, [1]), (4, [2])])
    with pytest.raises(AssertionError):   # at least one agent alive
        DropPlan.from_events(4, [(0, [0, 1, 2, 3])])


def test_drop_plan_epoch_index_concrete_and_traced():
    plan = DropPlan.from_events(8, [(0, []), (4, [7]), (12, [6, 7])])
    want = [0] * 4 + [1] * 8 + [2] * 8
    got_c = [plan.epoch_index(t) for t in range(20)]
    got_t = [int(jax.jit(plan.epoch_index)(jnp.int32(t))) for t in range(20)]
    assert got_c == want and got_t == want
    np.testing.assert_array_equal(plan.alive_at(5),
                                  [1, 1, 1, 1, 1, 1, 1, 0])
    np.testing.assert_array_equal(plan.always_alive(), np.arange(6))


def test_drop_plan_random_is_deterministic_with_anchors():
    a = DropPlan.random(16, 0.4, seed=3, n_epochs=5, epoch_len=4)
    b = DropPlan.random(16, 0.4, seed=3, n_epochs=5, epoch_len=4)
    assert a == b
    assert a.starts == (0, 4, 8, 12, 16)
    # the min_alive anchor agents are never dropped
    for _, alive in a.epochs:
        assert alive[0] and alive[1]
    assert set(a.always_alive()) >= {0, 1}
    # rate 0 is the all-alive plan
    z = DropPlan.random(8, 0.0, seed=0)
    assert all(all(al) for _, al in z.epochs)


# ---------------------------------------------------------------------------
# degrade_round: survivor-rank rewiring invariants
# ---------------------------------------------------------------------------

def test_degrade_all_alive_is_passthrough():
    topo = ring(8)
    assert degrade_round(topo, [True] * 8) is topo


def test_degraded_ring8_tail_drop_is_ring6():
    """Dropping the tail of ring(8) must reproduce ring(6) exactly on the
    survivor block — the identity behind the exact cross-size resume."""
    masked = degrade_round(ring(8), [1, 1, 1, 1, 1, 1, 0, 0])
    assert isinstance(masked, MaskedTopology)
    W = masked.dense_matrix()
    np.testing.assert_array_equal(W[:6, :6], ring(6).dense_matrix())
    eye = np.eye(8)
    np.testing.assert_array_equal(W[6:], eye[6:])
    np.testing.assert_array_equal(W[:, 6:], eye[:, 6:])
    # σ-merged terms: self 0.5, +1 → 1, −1 → 5 (mod 6)
    assert sorted((t.shift, t.weight) for t in masked.terms) == \
        [(0, 0.5), (1, 0.25), (5, 0.25)]


def test_degraded_round_doubly_stochastic_any_mask():
    rng = np.random.default_rng(0)
    for topo in (ring(8), exp_graph(16), hierarchical(2, 8),
                 hierarchical(4, 4, intra="ring")):
        n = topo.n_agents
        for _ in range(4):
            alive = rng.random(n) > 0.3
            alive[rng.integers(n)] = True     # ≥ 1 survivor
            masked = degrade_round(topo, alive)
            if masked is topo:
                continue
            W = masked.dense_matrix()
            ones = np.ones(n)
            np.testing.assert_allclose(W @ ones, ones, atol=1e-12)
            np.testing.assert_allclose(ones @ W, ones, atol=1e-12)
            assert np.all(W >= 0) and np.all(np.diag(W) > 0)
            dead = np.flatnonzero(~alive)
            np.testing.assert_array_equal(W[dead], np.eye(n)[dead])


def test_masked_engines_agree_with_dense():
    """The shifts engine's masked gather route == the dense oracle (the
    single-process half of the engine-equivalence contract)."""
    for topo, alive in ((ring(8), [1, 0, 1, 1, 0, 1, 1, 1]),
                        (exp_graph(16), [1] * 12 + [0] * 4),
                        (hierarchical(2, 8), [0, 1] * 8)):
        masked = degrade_round(topo, alive)
        x = {"a": jax.random.normal(jax.random.PRNGKey(0),
                                    (topo.n_agents, 5)),
             "b": jax.random.normal(jax.random.PRNGKey(1),
                                    (topo.n_agents, 2, 3))}
        want = mix_dense(masked, x)
        got = jax.jit(lambda t: mix_shifts(masked, t))(x)
        for k in x:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"{masked.name} {k}")


# ---------------------------------------------------------------------------
# ElasticSchedule: per-epoch Assumption-1 transfer
# ---------------------------------------------------------------------------

def _elastic_cases():
    out = []
    for base in (StaticSchedule(ring(8)), StaticSchedule(exp_graph(16)),
                 StaticSchedule(hierarchical(2, 16)), RoundRobinExp(8),
                 RoundRobinExp(32)):
        plan = DropPlan.random(base.n_agents, 0.25, seed=13, n_epochs=4,
                               epoch_len=base.period)
        out.append(ElasticSchedule(base, plan))
    return out


@pytest.mark.parametrize("sched", _elastic_cases(),
                         ids=lambda s: s.name.replace("(", "-").strip(")"))
def test_elastic_schedules_satisfy_assumption1(sched):
    """Acceptance: check_assumption1 provably holds for every degraded
    period — doubly stochastic rounds, positive diagonal, dead rows/cols
    identity, survivor-block period product contracting."""
    sched.check_assumption1()
    stats = sched.product_spectral_stats()
    assert stats["gap"] > 0
    for es in sched.epoch_stats():
        assert es["alive"] >= 2 and es["gap"] > 0, es


def test_elastic_round_index_concrete_traced_agree():
    base = RoundRobinExp(8)                   # period 3
    plan = DropPlan.from_events(8, [(0, []), (6, [2, 7])])
    sched = ElasticSchedule(base, plan)
    assert sched.period == 2 * base.period
    for t in range(12):
        r_c = sched.round_index(t)
        r_t = int(jax.jit(sched.round_index)(jnp.int32(t)))
        assert r_c == r_t == plan.epoch_index(t) * base.period \
            + t % base.period


def test_elastic_epoch_alignment_asserts():
    base = RoundRobinExp(8)                   # period 3
    with pytest.raises(AssertionError):
        ElasticSchedule(base, DropPlan.from_events(8, [(0, []), (4, [1])]))


def test_wire_bytes_drop_under_masking():
    """Dead agents' rows leave the wire: the masked round ships only the
    survivor permute rows (the us/step + wire claim of BENCH_elastic)."""
    base = StaticSchedule(ring(8))
    plan = DropPlan.from_events(8, [(0, [2, 5])])
    sched = ElasticSchedule(base, plan)
    sched.check_assumption1()
    d = 1024
    healthy = wire_bytes_per_step(base, 0, elems_per_agent=d,
                                  engine="ppermute")
    masked = wire_bytes_per_step(sched, 0, elems_per_agent=d,
                                 engine="ppermute")
    # ring ships 2 rows/agent; masked: 2 rows per SURVIVOR (6 of 8)
    assert healthy == 2 * 8 * d * 4
    assert masked == 2 * 6 * d * 4


def test_make_gossip_schedule_churn_wiring():
    """--churn reaches the trainer: inline JSON / dict / DropPlan all wrap
    the base schedule in a checked ElasticSchedule."""
    from repro.configs.base import RunConfig
    from repro.train import make_gossip_schedule

    run = RunConfig(global_batch=8, seq_len=8, algorithm="edm")
    plan = DropPlan.from_events(8, [(0, []), (4, [6, 7])])
    for churn in (plan, json.dumps(plan.to_json()), plan.to_json()):
        sched = make_gossip_schedule(run, 8, churn=churn)
        assert isinstance(sched, ElasticSchedule)
        assert sched.plan == plan
    assert not isinstance(make_gossip_schedule(run, 8), ElasticSchedule)


# ---------------------------------------------------------------------------
# StragglerPlan
# ---------------------------------------------------------------------------

def test_straggler_plan_table():
    plan = StragglerPlan(n_terms=3, late=((2, (1,)), (4, (0, 2))))
    np.testing.assert_array_equal(np.asarray(plan.late_at(2)),
                                  [False, True, False])
    np.testing.assert_array_equal(np.asarray(plan.late_at(4)),
                                  [True, False, True])
    for t in (0, 1, 3, 5, 100):               # past-the-table steps: no late
        assert not np.any(np.asarray(plan.late_at(t)))
    assert not np.any(np.asarray(jax.jit(plan.late_at)(jnp.int32(7))))
    with pytest.raises(AssertionError):
        StragglerPlan(n_terms=2, late=((0, (2,)),))


# ---------------------------------------------------------------------------
# degraded ppermute == dense == sharded oracle (8-device subprocess)
# ---------------------------------------------------------------------------

_ELASTIC_ENGINE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import (DropPlan, ElasticSchedule, RoundRobinExp,
                        StaticSchedule, degrade_round, make_overlap_mixer,
                        make_schedule_mixer, ring)
from repro.core.mixing import mix_dense, mix_dense_sharded, mix_ppermute

def flat_mesh(n):
    return Mesh(np.array(jax.devices()[:n]), ("data",))

# {static, round_robin} x {fused, unfused} x {B=1 (A=8), B=4 (A=32)}
for A, apd in ((8, 1), (32, 4)):
    for make_base in (lambda A=A: StaticSchedule(ring(A)),
                      lambda A=A: RoundRobinExp(A)):
        base = make_base()
        plan = DropPlan.random(A, 0.25, seed=11, n_epochs=3,
                               epoch_len=base.period)
        sched = ElasticSchedule(base, plan)
        sched.check_assumption1()
        mesh = flat_mesh(A // apd)
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (A, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (A, 2, 3))}
        for fused in (False, True):
            mix = make_schedule_mixer(sched, "ppermute", mesh=mesh,
                                      agent_axes="data",
                                      use_fused_kernel=fused)
            for r in range(sched.period):
                got = jax.jit(lambda t, r=r: mix(t, step=r))(tree)
                want = mix_dense(sched.rounds[r], tree)
                for k in tree:
                    np.testing.assert_allclose(
                        np.asarray(got[k]), np.asarray(want[k]),
                        rtol=1e-5, atol=1e-6,
                        err_msg=f"{sched.name} B={apd} fused={fused} "
                                f"round={r} {k}")
            # traced step routes through lax.switch into epoch 1
            t_tr = jnp.int32(base.period)
            got = jax.jit(mix)(tree, t_tr)
            want = mix_dense(sched.round(base.period), tree)
            for k in tree:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(want[k]),
                    rtol=1e-5, atol=1e-6,
                    err_msg=f"{sched.name} B={apd} fused={fused} traced")
    print(f"ELASTIC_AGREE A={A} B={apd}")

# HLO pin: exactly one collective-permute per nonzero survivor shift (B=1)
A = 8
sched = ElasticSchedule(StaticSchedule(ring(A)),
                        DropPlan.from_events(A, [(0, (2, 5))]))
masked = sched.rounds[0]
nz = sum(1 for t in masked.terms if t.shift != 0)
assert nz == 2, [t.shift for t in masked.terms]
mix = make_schedule_mixer(sched, "ppermute", mesh=flat_mesh(A),
                          agent_axes="data")
x = {"w": jax.random.normal(jax.random.PRNGKey(0), (A, 4))}
hlo = jax.jit(lambda t: mix(t, step=0)).lower(x).compile().as_text()
got = hlo.count("collective-permute(")
assert got == nz, (got, nz)
print("ELASTIC_HLO_OK")

# sharded oracle: masked ppermute on a pods x shards mesh == shard-resident
# dense oracle == dense oracle (4 pod-agents x 2 FSDP shards)
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
A, S = 4, 2
mesh = make_gossip_mesh(A, pods=A, shards=S)
axes = gossip_agent_axes(mesh, sharded=True)
masked = degrade_round(ring(A), [1, 1, 1, 0])
x = jax.random.normal(jax.random.PRNGKey(2), (A, 8, 16))
want = mix_dense(masked, x)
got_pp = mix_ppermute(masked, mesh, axes, x, shard_axes="data")
got_ds = mix_dense_sharded(masked, mesh, axes, "data", x)
np.testing.assert_allclose(np.asarray(got_pp), np.asarray(want),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(got_ds), np.asarray(want),
                           rtol=1e-5, atol=1e-6)
print("ELASTIC_SHARDED_OK")

# straggler: a late payload slot degrades to self-weight — equals the
# W_eff oracle, never reads the late buffer (NaN-poisoned), and the
# dense-engine late path agrees with the ppermute one
from jax.sharding import NamedSharding, PartitionSpec as P
A = 8
sched = StaticSchedule(ring(A))
mesh = flat_mesh(A)
issue, complete = make_overlap_mixer(sched, "ppermute", mesh=mesh,
                                     agent_axes="data")
x = jax.device_put(jax.random.normal(jax.random.PRNGKey(3), (A, 64, 128)),
                   NamedSharding(mesh, P("data")))
pays = issue(x, 0)
late_np = np.zeros(complete.n_terms, bool)
k_late = next(k for k, t in enumerate(sched.rounds[0].terms)
              if t.shift != 0)
late_np[k_late] = True
poisoned = pays.at[k_late].set(jnp.nan)
got = jax.jit(lambda p: complete(p, 0, late=jnp.asarray(late_np)))(poisoned)
assert bool(jnp.all(jnp.isfinite(got))), "late buffer leaked into combine"
n = A
idx = np.arange(n)
W_eff = np.zeros((n, n), np.float32)
for k, t in enumerate(sched.rounds[0].terms):
    if late_np[k]:
        W_eff[idx, idx] += t.weight
    else:
        W_eff[idx, sched.rounds[0].term_sources(t)] += t.weight
np.testing.assert_allclose(W_eff.sum(0), 1.0, atol=1e-6)
np.testing.assert_allclose(W_eff.sum(1), 1.0, atol=1e-6)
want = jnp.einsum("ij,j...->i...", jnp.asarray(W_eff), x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-5, atol=1e-6)
_, complete_d = make_overlap_mixer(sched, "dense")
got_d = complete_d(jax.device_get(x), 0, late=jnp.asarray(late_np))
np.testing.assert_allclose(np.asarray(got_d), np.asarray(want),
                           rtol=1e-5, atol=1e-6)

# masked overlap: issue/complete over an ElasticSchedule (per-agent weight
# columns) == the synchronous masked schedule mixer, every round
es = ElasticSchedule(StaticSchedule(ring(A)),
                     DropPlan.from_events(A, [(0, ()), (1, (2, 5))]))
mix_s = make_schedule_mixer(es, "ppermute", mesh=mesh, agent_axes="data")
issue_e, complete_e = make_overlap_mixer(es, "ppermute", mesh=mesh,
                                         agent_axes="data")
for s in range(es.period):
    got = jax.jit(lambda t, s=s: complete_e(issue_e(t, s), s))(x)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(mix_s(x, step=s)),
                               rtol=1e-5, atol=1e-6, err_msg=f"step {s}")
print("ELASTIC_STRAGGLER_OK")
"""


def test_elastic_engines_subprocess():
    """Acceptance: degraded ppermute == dense == sharded oracle over
    {static, round_robin} × {fused, unfused} × {B=1, B=4}; one
    collective-permute per nonzero survivor shift; straggler complete()
    matches the W_eff oracle without reading the late buffer."""
    r = subprocess.run([sys.executable, "-c", _ELASTIC_ENGINE_CODE],
                       cwd=REPO, env=ENV, capture_output=True, text=True,
                       timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for marker in ("ELASTIC_AGREE A=8 B=1", "ELASTIC_AGREE A=32 B=4",
                   "ELASTIC_HLO_OK", "ELASTIC_SHARDED_OK",
                   "ELASTIC_STRAGGLER_OK"):
        assert marker in r.stdout, (marker, r.stdout[-2000:])


# ---------------------------------------------------------------------------
# cross-size checkpoints (DESIGN §8 join/leave)
# ---------------------------------------------------------------------------

def test_resize_state_shrink_and_grow_policies():
    k = jax.random.PRNGKey(0)
    state = {
        "params": {"w": jax.random.normal(k, (6, 3))},
        "opt": {"psi": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                               (6, 3))},
                "m": {"w": jax.random.normal(jax.random.fold_in(k, 2),
                                             (6, 3))}},
        "step": jnp.int32(5),
    }
    from repro.train import checkpoint

    # shrink: selected rows verbatim, bit-exact
    small = checkpoint.resize_state(state, [0, 2, 4], 3)
    for slot in ("params",):
        np.testing.assert_array_equal(
            np.asarray(small[slot]["w"]),
            np.asarray(state[slot]["w"])[[0, 2, 4]])
    np.testing.assert_array_equal(np.asarray(small["opt"]["m"]["w"]),
                                  np.asarray(state["opt"]["m"]["w"])[[0, 2, 4]])

    # grow: joiners at the consensus mean, ψ := x, m = 0
    big = checkpoint.resize_state(state, range(6), 8)
    w = np.asarray(state["params"]["w"])
    np.testing.assert_array_equal(np.asarray(big["params"]["w"])[:6], w)
    np.testing.assert_allclose(np.asarray(big["params"]["w"])[6:],
                               np.broadcast_to(w.mean(0), (2, 3)),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(big["opt"]["psi"]["w"])[6:],
                                  np.asarray(big["params"]["w"])[6:])
    np.testing.assert_array_equal(np.asarray(big["opt"]["psi"]["w"])[:6],
                                  np.asarray(state["opt"]["psi"]["w"]))
    np.testing.assert_array_equal(np.asarray(big["opt"]["m"]["w"])[6:], 0.0)
    assert int(big["step"]) == 5


def _tiny_model():
    from repro.configs.base import ModelConfig
    from repro.models import build_model
    cfg = ModelConfig(name="el-tiny", family="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")
    return build_model(cfg)


def _elastic_run(n_agents, **kw):
    from repro.configs.base import RunConfig
    return RunConfig(global_batch=n_agents, seq_len=8, algorithm="edm",
                     alpha=0.1, gossip_engine="shifts", packed_bus=True,
                     remat=False, **kw)


def test_checkpoint_same_size_resized_roundtrip_bitwise(tmp_path):
    """A′ == A with default survivors short-circuits to load_state — the
    resized loader round-trips bit-identically."""
    from repro.data import SyntheticLM
    from repro.train import (build_train_step, bus_layout_for, checkpoint,
                             init_state, make_gossip_schedule)

    model = _tiny_model()
    A = 8
    run = _elastic_run(A)
    layout = bus_layout_for(model, A)
    batch = SyntheticLM(vocab_size=64, seq_len=8, n_agents=A).sample(
        jax.random.PRNGKey(1), 1)
    sched = make_gossip_schedule(run, A)
    step = jax.jit(build_train_step(model, run, sched))
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    for _ in range(3):
        state, _ = step(state, batch)
    path = str(tmp_path / "same.npz")
    checkpoint.save_state(path, state, layout=layout)
    like = init_state(model, run, A, jax.random.PRNGKey(0))
    restored = checkpoint.load_state_resized(path, like, layout=layout)
    np.testing.assert_array_equal(np.asarray(restored["params"]),
                                  np.asarray(state["params"]))
    for slot in state["opt"]:
        np.testing.assert_array_equal(np.asarray(restored["opt"][slot]),
                                      np.asarray(state["opt"][slot]))
    assert int(restored["step"]) == 3


def test_resumed_churn_trajectory_matches_uninterrupted(tmp_path):
    """The headline §8 exactness contract: an A=8 run whose plan drops the
    tail agents at step 4, vs the same run checkpointed at step 4 and
    resumed at A′=6 — the survivors' trajectories agree EXACTLY, because
    the degraded ring(8) restricted to its 6 survivors IS ring(6) and the
    shrink resize is bit-exact."""
    from repro.data import SyntheticLM
    from repro.train import (build_train_step, bus_layout_for, checkpoint,
                             init_state, make_gossip_schedule)

    model = _tiny_model()
    A = 8
    churn = DropPlan.from_events(A, [(0, []), (4, [6, 7])])
    run8 = _elastic_run(A)
    layout = bus_layout_for(model, A)   # agent-count-agnostic
    data = SyntheticLM(vocab_size=64, seq_len=8, n_agents=A)
    batches = [data.sample(jax.random.PRNGKey(100 + t), 1) for t in range(8)]

    # uninterrupted churn run: 8 agents, tail degraded from step 4
    sched8 = make_gossip_schedule(run8, A, churn=churn)
    step8 = jax.jit(build_train_step(model, run8, sched8))
    s_full = init_state(model, run8, A, jax.random.PRNGKey(0))
    path = str(tmp_path / "elastic.npz")
    for t in range(8):
        if t == 4:
            checkpoint.save_state(path, s_full, layout=layout)
        s_full, _ = step8(s_full, batches[t])

    # resumed run: load the step-4 checkpoint into a 6-agent build
    run6 = _elastic_run(6)
    sched6 = make_gossip_schedule(run6, 6)
    step6 = jax.jit(build_train_step(model, run6, sched6))
    like6 = init_state(model, run6, 6, jax.random.PRNGKey(0))
    s_res = checkpoint.load_state_resized(path, like6, layout=layout)
    assert int(s_res["step"]) == 4
    for t in range(4, 8):
        b6 = jax.tree.map(lambda l: l[:6], batches[t])
        s_res, _ = step6(s_res, b6)

    np.testing.assert_array_equal(np.asarray(s_res["params"]),
                                  np.asarray(s_full["params"])[:6])
    for slot in s_res["opt"]:
        np.testing.assert_array_equal(
            np.asarray(s_res["opt"][slot]),
            np.asarray(s_full["opt"][slot])[:6], err_msg=slot)


def test_rejoin_after_shrink_seeds_consensus(tmp_path):
    """Grow leg of join/leave: a 6-agent checkpoint resumed at A′=8 puts
    joiners at the survivors' consensus mean with ψ := x and m = 0, and the
    grown state trains without NaNs."""
    from repro.data import SyntheticLM
    from repro.train import (build_train_step, bus_layout_for, checkpoint,
                             init_state, make_gossip_schedule)

    model = _tiny_model()
    run6 = _elastic_run(6)
    layout = bus_layout_for(model, 6)
    data = SyntheticLM(vocab_size=64, seq_len=8, n_agents=8)
    batch8 = data.sample(jax.random.PRNGKey(1), 1)
    batch6 = jax.tree.map(lambda l: l[:6], batch8)
    sched6 = make_gossip_schedule(run6, 6)
    step6 = jax.jit(build_train_step(model, run6, sched6))
    s6 = init_state(model, run6, 6, jax.random.PRNGKey(0))
    for _ in range(3):
        s6, _ = step6(s6, batch6)
    path = str(tmp_path / "shrunk.npz")
    checkpoint.save_state(path, s6, layout=layout)

    run8 = _elastic_run(8)
    like8 = init_state(model, run8, 8, jax.random.PRNGKey(0))
    s8 = checkpoint.load_state_resized(path, like8, layout=layout)
    p8 = np.asarray(s8["params"])
    np.testing.assert_array_equal(p8[:6], np.asarray(s6["params"]))
    np.testing.assert_allclose(p8[6:],
                               np.broadcast_to(p8[:6].mean(0), p8[6:].shape),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(s8["opt"]["psi"])[6:], p8[6:])
    np.testing.assert_array_equal(np.asarray(s8["opt"]["m"])[6:], 0.0)
    sched8 = make_gossip_schedule(run8, 8)
    step8 = jax.jit(build_train_step(model, run8, sched8))
    for _ in range(2):
        s8, m = step8(s8, batch8)
        assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# churn divergence gates (satellite e — the raising gate behind --churn)
# ---------------------------------------------------------------------------

def test_churn_divergence_gates():
    code = (
        "from benchmarks.gossip_micro import churn_divergence_gates\n"
        "gates = churn_divergence_gates(verbose=False)\n"
        "assert gates['quadratic']['ratio'] <= 3.0\n"
        "assert gates['logistic']['ratio'] <= 1.10\n"
        "assert gates['quadratic']['always_alive'] >= 2\n"
        "print('CHURN_GATES_OK')\n"
    )
    r = subprocess.run([sys.executable, "-c", code], cwd=REPO, env=ENV,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "CHURN_GATES_OK" in r.stdout


# ---------------------------------------------------------------------------
# property sweeps (slow tail): deterministic + hypothesis
# ---------------------------------------------------------------------------

def _property_topologies():
    out = []
    for n in (4, 8, 16, 32):
        out.append(("ring", ring(n)))
        out.append(("exp", exp_graph(n)))
    for p, d in ((2, 2), (2, 8), (4, 4), (4, 8)):
        out.append(("hier", hierarchical(p, d)))
    return out


def _check_degrade_invariants(topo, alive):
    """The per-round invariant set behind both property sweeps."""
    n = topo.n_agents
    masked = degrade_round(topo, alive)
    if masked is topo:
        assert all(alive)
        return
    W = masked.dense_matrix()
    ones = np.ones(n)
    np.testing.assert_allclose(W @ ones, ones, atol=1e-12)
    np.testing.assert_allclose(ones @ W, ones, atol=1e-12)
    assert np.all(W >= 0) and np.all(np.diag(W) > 0)
    dead = np.flatnonzero(~np.asarray(alive, bool))
    np.testing.assert_array_equal(W[dead], np.eye(n)[dead])
    np.testing.assert_array_equal(W[:, dead], np.eye(n)[:, dead])
    # survivor block of the ±1-connected round contracts when m >= 2
    mask = LivenessMask.of(alive)
    if mask.m >= 2 and any(t.shift != 0 for t in masked.terms):
        from repro.core import matrix_lam
        sub = W[np.ix_(mask.survivors, mask.survivors)]
        assert matrix_lam(np.linalg.matrix_power(sub, mask.m)) < 1 - 1e-9


@pytest.mark.slow
def test_degrade_invariants_seeded_sweep():
    """Deterministic property sweep over {ring, exp, hierarchical} ×
    n ∈ {4..32} × random masks — runs without hypothesis installed."""
    rng = np.random.default_rng(42)
    for _, topo in _property_topologies():
        n = topo.n_agents
        for _ in range(6):
            alive = rng.random(n) > rng.uniform(0.1, 0.6)
            alive[int(rng.integers(n))] = True
            _check_degrade_invariants(topo, alive)


@pytest.mark.slow
def test_degrade_invariants_hypothesis():
    """Hypothesis sweep of the same invariants (optional `test` extra)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    topos = _property_topologies()

    @settings(max_examples=40, deadline=None)
    @given(i=st.integers(0, len(topos) - 1), data=st.data())
    def run(i, data):
        _, topo = topos[i]
        n = topo.n_agents
        alive = list(data.draw(
            st.lists(st.booleans(), min_size=n, max_size=n)))
        alive[data.draw(st.integers(0, n - 1))] = True
        _check_degrade_invariants(topo, alive)

    run()
