"""Packed parameter bus (DESIGN §5): layout round-trips, bus-resident EDM
step equivalence, and the launch/permute-count acceptance criteria.

* ``pack_tree ∘ unpack_tree == id`` over ragged leaf shapes/dtypes
  (parametrized always; property-based under hypothesis when installed);
* bus-resident vs leaf-wise train step equivalence across engines ×
  schedules × fused/unfused × agents-per-device (subprocess on a forced
  multi-device host platform);
* HLO acceptance: one bus train step contains exactly one
  ``collective-permute`` per nonzero-shift gossip term (zero-shift terms
  are device-local and never were permutes);
* trace acceptance: one ``edm_update`` pallas_call per bus step vs one per
  leaf for the tree-resident path;
* the ``gossip_every`` local-EDM branch runs under ``lax.cond`` — skip
  steps execute only the identity update;
* layout-independent checkpointing and bus state_specs.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bus, make_edm_bus, make_mixer, make_optimizer, ring)
from repro.kernels import ops

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


def _ragged_tree(A, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    return {
        "emb": jax.random.normal(ks[0], (A, 17, 9)),
        "blocks": [
            {"w": jax.random.normal(ks[1], (A, 33)).astype(jnp.bfloat16),
             "b": jax.random.normal(ks[2], (A,))},
            {"w": jax.random.normal(ks[3], (A, 2, 3, 5)),
             "b": jax.random.normal(ks[4], (A, 1)).astype(jnp.float16)},
        ],
        "head": jax.random.normal(ks[5], (A, 129)),
    }


# ---------------------------------------------------------------------------
# layout + pack/unpack round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("A", [1, 3, 8])
def test_pack_unpack_roundtrip_ragged(A):
    tree = _ragged_tree(A)
    layout = bus.make_layout(tree, block_rows=8)
    packed = bus.pack_tree(layout, tree)
    assert packed.shape == (A, layout.rows, 128)
    assert packed.dtype == jnp.float32
    assert layout.rows % layout.block_rows == 0
    back = bus.unpack_tree(layout, packed)
    flat_want, td_want = jax.tree_util.tree_flatten(tree)
    flat_got, td_got = jax.tree_util.tree_flatten(back)
    assert td_want == td_got
    for w, g in zip(flat_want, flat_got):
        assert g.dtype == w.dtype and g.shape == w.shape
        # sub-f32 leaves round-trip through the f32 bus losslessly
        np.testing.assert_array_equal(np.asarray(g, np.float32),
                                      np.asarray(w, np.float32))


def test_layout_alignment_and_cache():
    tree = _ragged_tree(4)
    layout = bus.make_layout(tree, block_rows=64)
    for slot in layout.slots:
        assert slot.row % 8 == 0 and slot.rows % 8 == 0  # 8×128 tiles
        assert slot.rows * 128 >= slot.size
    # slots are disjoint and ordered
    end = 0
    for slot in layout.slots:
        assert slot.row >= end
        end = slot.row + slot.rows
    assert end <= layout.rows
    assert layout.logical_elems == sum(
        l.size // 4 for l in jax.tree.leaves(tree))
    # the cache returns the identical layout object for equal signatures,
    # and is agent-count-agnostic
    assert bus.make_layout(_ragged_tree(4, key=9), block_rows=64) is layout
    assert bus.make_layout(_ragged_tree(7), block_rows=64) is layout  # A-agnostic


def test_pack_is_jit_traceable_and_pad_zero():
    tree = _ragged_tree(2)
    layout = bus.make_layout(tree, block_rows=8)
    packed = jax.jit(lambda t: bus.pack_tree(layout, t))(tree)
    flat = np.asarray(packed).reshape(2, -1)
    mask = np.ones(flat.shape[1], bool)
    for slot in layout.slots:
        mask[slot.row * 128: slot.row * 128 + slot.size] = False
    assert np.all(flat[:, mask] == 0), "pad regions must be zero"
    back = jax.jit(lambda b: bus.unpack_tree(layout, b))(packed)
    np.testing.assert_array_equal(np.asarray(back["head"]),
                                  np.asarray(tree["head"]))


def test_leaf_views_match_unpack():
    tree = _ragged_tree(3)
    layout = bus.make_layout(tree, block_rows=8)
    packed = bus.pack_tree(layout, tree)
    views = bus.leaf_views(layout, packed)
    unpacked = bus.unpack_tree(layout, packed)
    for v, u in zip(jax.tree.leaves(views), jax.tree.leaves(unpacked)):
        assert v.dtype == layout.dtype  # views stay in bus dtype
        np.testing.assert_allclose(np.asarray(v, np.float32),
                                   np.asarray(u, np.float32), rtol=1e-2,
                                   atol=1e-2)


def test_padded_size_accounting():
    assert ops.padded_size(1, 8) == 8 * 128
    assert ops.padded_size(8 * 128, 8) == 8 * 128
    assert ops.padded_size(8 * 128 + 1, 8) == 2 * 8 * 128
    # _pack must agree with the model the benchmarks use
    leaf = jnp.ones((3, 50))
    packed, n = ops._pack(leaf, 8)
    assert n == 150 and packed.size == ops.padded_size(150, 8)


# ---------------------------------------------------------------------------
# property-based round trip (hypothesis, optional)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - optional extra
    HAVE_HYP = False


if HAVE_HYP:
    leaf_shapes = st.lists(
        st.lists(st.integers(1, 7), min_size=0, max_size=3).map(tuple),
        min_size=1, max_size=6)
    leaf_dtypes = st.sampled_from([jnp.float32, jnp.bfloat16, jnp.float16])

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(shapes=leaf_shapes, dtype=leaf_dtypes,
           A=st.integers(1, 5), seed=st.integers(0, 2**16))
    def test_roundtrip_property(shapes, dtype, A, seed):
        """pack_tree ∘ unpack_tree == id for any ragged leaf set (exactness:
        every sub-f32 dtype embeds in the f32 bus)."""
        ks = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
        tree = {f"l{i}": jax.random.normal(k, (A,) + s).astype(
                    dtype if i % 2 else jnp.float32)
                for i, (k, s) in enumerate(zip(ks, shapes))}
        layout = bus.make_layout(tree, block_rows=8)
        back = bus.unpack_tree(layout, bus.pack_tree(layout, tree))
        for k in tree:
            assert back[k].dtype == tree[k].dtype
            np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                          np.asarray(tree[k], np.float32))


# ---------------------------------------------------------------------------
# bus-resident EDM == leaf-wise EDM (optimizer level, dense oracle mixer)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, True], ids=["unfused", "fused"])
def test_edm_bus_matches_leafwise(fused):
    A = 8
    topo = ring(A)
    tree = jax.tree.map(lambda x: x.astype(jnp.float32), _ragged_tree(A))
    grads = jax.tree.map(lambda x: 0.1 * x, tree)
    mix = make_mixer(topo, "dense")

    opt = make_optimizer("edm", alpha=0.05, beta=0.9, mix=mix,
                         use_fused_kernel=fused)
    x, st = tree, opt.init(tree)
    for _ in range(4):
        x, st = opt.step(x, grads, st)

    layout = bus.make_layout(tree, block_rows=8)
    bopt = make_edm_bus(0.05, 0.9, mix, block_rows=layout.block_rows,
                        use_fused_kernel=fused)
    xb = bus.pack_tree(layout, tree)
    stb = bopt.init(xb)
    gb = bus.pack_tree(layout, grads)
    for _ in range(4):
        xb, stb = bopt.step(xb, gb, stb)

    got = bus.unpack_tree(layout, xb)
    for w, g in zip(jax.tree.leaves(x), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-5, atol=1e-6)
    # the pad region stays zero across steps (zero-preservation contract)
    flat = np.asarray(xb).reshape(A, -1)
    mask = np.ones(flat.shape[1], bool)
    for slot in layout.slots:
        mask[slot.row * 128: slot.row * 128 + slot.size] = False
    assert np.all(flat[:, mask] == 0)


@pytest.mark.parametrize("fmt", ["f32", "bf16", "int8"])
def test_edm_bus_ef_pad_stays_zero(fmt):
    """Zero-preservation extends to every wire format (DESIGN §9): the
    codec maps pad zeros to exact zero on the wire — int8's all-zero
    blocks take scale 0 with no 0/0 NaN, and zeros inside mixed blocks
    quantize to q = 0 — so the bus pad region AND the carried residual
    stay identically zero across EF-compressed steps."""
    from repro.core import make_edm_bus_ef
    from repro.core.wire import make_codec

    A = 4
    topo = ring(A)
    tree = jax.tree.map(lambda x: x.astype(jnp.float32), _ragged_tree(A))
    grads = jax.tree.map(lambda x: 0.1 * x, tree)
    layout = bus.make_layout(tree, block_rows=8)
    codec = make_codec(fmt, layout.block_rows)
    mix = make_mixer(topo, "dense", wire=codec)
    opt = make_edm_bus_ef(0.05, 0.9, mix, codec,
                          block_rows=layout.block_rows)
    xb = bus.pack_tree(layout, tree)
    stb = opt.init(xb)
    gb = bus.pack_tree(layout, grads)
    for _ in range(4):
        xb, stb = opt.step(xb, gb, stb)
    mask = np.ones(layout.rows * 128, bool)
    for slot in layout.slots:
        mask[slot.row * 128: slot.row * 128 + slot.size] = False
    for name, buf in (("x", xb), ("e", stb["e"]), ("m", stb["m"]),
                      ("psi", stb["psi"])):
        flat = np.asarray(buf).reshape(A, -1)
        assert np.all(flat[:, mask] == 0), (fmt, name)


# ---------------------------------------------------------------------------
# one edm_update pallas_call per bus step (trace-count acceptance)
# ---------------------------------------------------------------------------

def _tiny_setup(packed, gossip_every=1, engine="dense"):
    from repro.configs.base import ModelConfig, RunConfig
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train import build_train_step, init_state, make_gossip_schedule

    cfg = ModelConfig(name="bus-tiny", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, dtype="float32")
    model = build_model(cfg)
    A = 4
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.2,
                    gossip_engine=engine, gossip_every=gossip_every,
                    packed_bus=packed, remat=False)
    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8,
                        n_agents=A).sample(jax.random.PRNGKey(1), 1)
    step = build_train_step(model, run, sched, use_fused_kernel=True)
    return model, state, batch, step


def test_single_edm_kernel_call_per_bus_step(monkeypatch):
    """Acceptance: the bus-resident step issues ONE fused edm_update
    pallas_call for the whole tree; the leaf-wise step issues one per leaf."""
    calls = {"bus": 0, "leaf": 0}
    orig_bus, orig_leaf = ops.edm_update_bus, ops.edm_update

    def count_bus(*a, **k):
        calls["bus"] += 1
        return orig_bus(*a, **k)

    def count_leaf(*a, **k):
        calls["leaf"] += 1
        return orig_leaf(*a, **k)

    monkeypatch.setattr(ops, "edm_update_bus", count_bus)
    monkeypatch.setattr(ops, "edm_update", count_leaf)

    model, state, batch, step = _tiny_setup(packed=True)
    jax.jit(step).lower(state, batch)
    assert calls["bus"] == 1 and calls["leaf"] == 0

    model, state, batch, step = _tiny_setup(packed=False)
    n_leaves = len(jax.tree.leaves(state["params"]))
    jax.jit(step).lower(state, batch)
    assert calls["bus"] == 1  # unchanged
    assert calls["leaf"] == n_leaves and n_leaves > 1


def test_gossip_every_uses_lax_cond():
    """Skip steps run only the identity update: the jaxpr of a
    gossip_every>1 step carries a `cond` primitive (single-branch
    execution), not a dual-evaluation where over both updates — and the
    4-step trajectory matches the explicit skip/gossip simulation."""
    model, state, batch, step = _tiny_setup(packed=False, gossip_every=2)
    jaxpr = str(jax.make_jaxpr(step)(state, batch))
    assert "cond" in jaxpr

    # trajectory equivalence against the explicit per-step construction
    from repro.train import make_topology
    sj = jax.jit(step)
    states = [state]
    for _ in range(4):
        s, _ = sj(states[-1], batch)
        states.append(s)

    # reference: hand-rolled — identity mix on even steps, W on odd
    model2, state2, batch2, _ = _tiny_setup(packed=False, gossip_every=2)
    from repro.configs.base import RunConfig

    run_g = RunConfig(global_batch=4, seq_len=8, algorithm="edm", alpha=0.2,
                      gossip_every=1, packed_bus=False, remat=False)
    topo = make_topology(run_g, 4)
    mix_w = make_mixer(topo, "shifts")
    grad_fn = jax.vmap(jax.value_and_grad(
        lambda p, b: model.loss(p, b, remat=False, remat_policy="full")))
    x, opt_st = state2["params"], state2["opt"]
    for t in range(4):
        _, g = grad_fn(x, batch)
        mix = mix_w if t % 2 == 1 else (lambda tr: tr)
        o = make_optimizer("edm", alpha=0.2, beta=0.9, mix=mix,
                           use_fused_kernel=True)
        x, opt_st = o.step(x, g, opt_st)
    for w, g in zip(jax.tree.leaves(x),
                    jax.tree.leaves(states[-1]["params"])):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint + state_specs
# ---------------------------------------------------------------------------

def test_checkpoint_layout_independent(tmp_path):
    """Checkpoints store the logical tree: a bus-resident save restores
    into a tree-resident run and vice versa (DESIGN §5 format note)."""
    from repro.train import checkpoint

    tree = jax.tree.map(lambda x: x.astype(jnp.float32), _ragged_tree(4))
    layout = bus.make_layout(tree, block_rows=8)
    packed = bus.pack_tree(layout, tree)

    p1 = str(tmp_path / "from_bus.npz")
    checkpoint.save(p1, packed, layout=layout)
    # ...restores as a logical tree
    restored_tree = checkpoint.load(p1, tree)
    for w, g in zip(jax.tree.leaves(tree), jax.tree.leaves(restored_tree)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # ...and back into a bus buffer
    restored_bus = checkpoint.load(p1, jnp.zeros_like(packed), layout=layout)
    np.testing.assert_array_equal(np.asarray(restored_bus),
                                  np.asarray(packed))

    # a tree-resident save loads into the bus too
    p2 = str(tmp_path / "from_tree.npz")
    checkpoint.save(p2, tree)
    restored_bus2 = checkpoint.load(p2, jnp.zeros_like(packed), layout=layout)
    np.testing.assert_array_equal(np.asarray(restored_bus2),
                                  np.asarray(packed))


def test_state_specs_match_bus_state_structure():
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.configs.base import RunConfig
    from repro.models import build_model
    from repro.train import init_state, state_specs

    model = build_model(get_smoke_config("smollm_360m"))
    run = RunConfig(algorithm="edm", packed_bus=True, remat=False)
    state = jax.eval_shape(
        lambda: init_state(model, run, 4, jax.random.PRNGKey(0)))
    specs = state_specs(model, run, multi_pod=False)
    # tree.map raises on structure mismatch (the dry-run relies on this)
    jax.tree.map(lambda sds, sp: None, state, specs,
                 is_leaf=lambda x: isinstance(x, P))
    assert state["params"].ndim == 3 and state["params"].shape[-1] == 128
    assert specs["params"] == P("data")


def test_packed_bus_resolution():
    from repro.configs.base import RunConfig
    from repro.train import use_packed_bus

    assert use_packed_bus(RunConfig(algorithm="edm",
                                    gossip_engine="ppermute"))
    assert not use_packed_bus(RunConfig(algorithm="edm",
                                        gossip_engine="shifts"))
    assert not use_packed_bus(RunConfig(algorithm="dsgd",
                                        gossip_engine="ppermute"))
    assert use_packed_bus(RunConfig(algorithm="edm", packed_bus=True))
    assert not use_packed_bus(RunConfig(algorithm="edm",
                                        gossip_engine="ppermute",
                                        packed_bus=False))
    with pytest.raises(AssertionError):
        use_packed_bus(RunConfig(algorithm="dsgd", packed_bus=True))


# ---------------------------------------------------------------------------
# train-step equivalence matrix + HLO permute count (multi-device subprocess)
# ---------------------------------------------------------------------------

_MATRIX_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, RunConfig
from repro.core import bus as parambus, ring
from repro.data import SyntheticLM
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
from repro.models import build_model
from repro.train import (build_train_step, bus_layout_for, init_state,
                         make_gossip_schedule)

cfg = ModelConfig(name="bus-matrix", family="dense", n_layers=1,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=64, dtype="float32")
model = build_model(cfg)
A = 8
batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8,
                    n_agents=A).sample(jax.random.PRNGKey(1), 1)

def run_steps(engine, schedule, fused, apd, packed, pods=1):
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.2,
                    gossip_engine=engine, gossip_schedule=schedule,
                    agents_per_device=apd, packed_bus=packed, remat=False)
    sched = make_gossip_schedule(run, A, pods=pods)
    mesh = axes = None
    if engine == "ppermute":
        mesh = make_gossip_mesh(A, pods=pods if apd == 1 else 1,
                                agents_per_device=apd)
        axes = gossip_agent_axes(mesh)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, sched,
                                    use_fused_kernel=fused,
                                    mesh=mesh, agent_axes=axes))
    losses = []
    for _ in range(3):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    params = state["params"]
    if packed:
        params = parambus.unpack_tree(bus_layout_for(model, A), params)
    return losses, params

CONFIGS = [
    ("dense", 1, False), ("shifts", 1, False),
    ("ppermute", 1, False), ("ppermute", 1, True),
    ("ppermute", 4, False), ("ppermute", 4, True),
]
for schedule, pods in (("static", 1), ("round_robin", 1), ("alt_hier", 2)):
    ref_losses, ref_params = run_steps("dense", schedule, False, 1,
                                       packed=False, pods=pods)
    for engine, apd, fused in CONFIGS:
        losses, params = run_steps(engine, schedule, fused, apd, packed=True,
                                   pods=pods)
        np.testing.assert_allclose(losses, ref_losses, rtol=1e-5, atol=1e-6,
            err_msg=f"losses {schedule}/{engine}/B={apd}/fused={fused}")
        for kw, kg in zip(jax.tree.leaves(ref_params),
                          jax.tree.leaves(params)):
            np.testing.assert_allclose(
                np.asarray(kg), np.asarray(kw), rtol=1e-4, atol=1e-5,
                err_msg=f"params {schedule}/{engine}/B={apd}/fused={fused}")
        print(f"MATRIX_AGREE {schedule}/{engine}/B={apd}/fused={fused}")
print("BUS_MATRIX_OK")
"""


def test_bus_train_step_equivalence_matrix():
    """Acceptance: the bus-resident train step matches the leaf-wise dense
    oracle to f32 tolerance on every engine × {static, round_robin} ×
    {fused, unfused} × {B=1, B=4}."""
    r = subprocess.run([sys.executable, "-c", _MATRIX_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "BUS_MATRIX_OK" in r.stdout


_HLO_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, RunConfig
from repro.core import exp_graph, ring
from repro.data import SyntheticLM
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
from repro.models import build_model
from repro.train import build_train_step, init_state, make_gossip_schedule
from repro.core.schedule import StaticSchedule

cfg = ModelConfig(name="bus-hlo", family="dense", n_layers=1,
                  d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                  vocab_size=64, dtype="float32")
model = build_model(cfg)
A = 8
batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8,
                    n_agents=A).sample(jax.random.PRNGKey(1), 1)
mesh = make_gossip_mesh(A)
axes = gossip_agent_axes(mesh)

for topo in (ring(A), exp_graph(A)):
    n_perm = sum(1 for t in topo.terms if t.shift != 0)
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.2,
                    topology=topo.name, gossip_engine="ppermute",
                    packed_bus=True, remat=False)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = build_train_step(model, run, StaticSchedule(topo), mesh=mesh,
                            agent_axes=axes)
    hlo = jax.jit(step).lower(state, batch).compile().as_text()
    got = hlo.count("collective-permute(")
    assert got == n_perm, (topo.name, got, n_perm)
    print(f"HLO_PERMUTES {topo.name}: {got} == {n_perm}")
print("BUS_HLO_OK")
"""


def test_bus_step_one_permute_per_gossip_term():
    """Acceptance: one full bus train step compiles to exactly one
    collective-permute per nonzero-shift gossip term (ring: 2, exp(8): 5) —
    the leaf-count factor is gone from the wire schedule."""
    r = subprocess.run([sys.executable, "-c", _HLO_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "BUS_HLO_OK" in r.stdout
