"""Unit tests for repro.core: topologies, mixing engines, optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALGORITHMS, disconnected, exp_graph, fully_connected, hierarchical,
    make_mixer, make_optimizer, mix_dense, mix_shifts, ring, torus2d,
)
from repro.core import metrics

jax.config.update("jax_enable_x64", False)


TOPOLOGIES = [
    ring(8), ring(32), exp_graph(16), torus2d(2, 8), torus2d(4, 4),
    fully_connected(8), hierarchical(2, 16), hierarchical(4, 4, intra="ring"),
    disconnected(8),
]


@pytest.mark.parametrize("topo", TOPOLOGIES, ids=lambda t: f"{t.name}-{t.n_agents}")
def test_assumption1(topo):
    """Every shipped topology satisfies the paper's Assumption 1."""
    topo.check_assumption1()


def test_ring_spectral_gap_scaling():
    """Paper Remark 1: ring spectral gap 1-λ = Θ(1/n²)."""
    g8, g32 = ring(8).spectral_gap(), ring(32).spectral_gap()
    ratio = g8 / g32
    assert 8 < ratio < 32, ratio  # ~ (32/8)² = 16


def test_ring32_lambda_matches_paper():
    # paper simulations: n=32 ring has λ ≈ 0.99
    lam = ring(32).lam()
    assert 0.985 < lam < 0.9999, lam


def test_full_is_exact_average():
    topo = fully_connected(8)
    x = {"w": jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)}
    mixed = mix_shifts(topo, x)
    np.testing.assert_allclose(mixed["w"], jnp.mean(x["w"], 0, keepdims=True)
                               * jnp.ones((8, 1)), rtol=1e-6)


@pytest.mark.parametrize("topo", TOPOLOGIES, ids=lambda t: f"{t.name}-{t.n_agents}")
def test_mixing_engines_agree(topo):
    """roll-based (production collective-permute path) == dense W oracle."""
    key = jax.random.PRNGKey(0)
    tree = {
        "a": jax.random.normal(key, (topo.n_agents, 5)),
        "b": jax.random.normal(key, (topo.n_agents, 2, 3)),
    }
    d = mix_dense(topo, tree)
    s = mix_shifts(topo, tree)
    for k in tree:
        np.testing.assert_allclose(d[k], s[k], rtol=2e-5, atol=2e-6)


def test_mixing_preserves_mean():
    """Double stochasticity ⇒ gossip preserves the agent mean exactly —
    the invariant behind x̄(t+1) = x̄(t) − α m̄(t)."""
    topo = ring(16)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 7))
    mixed = mix_shifts(topo, x)
    np.testing.assert_allclose(jnp.mean(mixed, 0), jnp.mean(x, 0), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# optimizer-level properties on the paper's quadratic problem
# ---------------------------------------------------------------------------

def _quadratic_problem(n=16, d=6, zeta=0.0, seed=0):
    """f_i(x) = ½‖A_i x − b_i‖²; hetero controlled via per-agent optima."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, 2 * d, d)).astype(np.float32)
    x_star = rng.normal(size=(d,)).astype(np.float32)
    offsets = rng.normal(size=(n, d)).astype(np.float32)
    x_i = x_star[None] + zeta * offsets
    b = np.einsum("npd,nd->np", A, x_i).astype(np.float32)
    A, b = jnp.asarray(A), jnp.asarray(b)

    def per_agent_grad(x):  # x: (n, d)
        r = jnp.einsum("npd,nd->np", A, x) - b
        return jnp.einsum("npd,np->nd", A, r) / A.shape[1]

    # global optimum of (1/n)Σ f_i
    AtA = np.einsum("npd,npe->de", np.asarray(A), np.asarray(A))
    Atb = np.einsum("npd,np->d", np.asarray(A), np.asarray(b))
    x_opt = jnp.asarray(np.linalg.solve(AtA, Atb))
    return per_agent_grad, x_opt


def _run(alg, grad_fn, x0, topo, alpha, beta, steps, noise=0.0, seed=0):
    mix = make_mixer(topo)
    opt = make_optimizer(alg, alpha=alpha, beta=beta, mix=mix)
    state = opt.init(x0)
    x = x0
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def body(carry, key):
        x, state = carry
        g = grad_fn(x)
        if noise > 0:
            g = g + noise * jax.random.normal(key, g.shape)
        x, state = opt.step(x, g, state)
        return (x, state), None

    keys = jax.random.split(key, steps)
    (x, state), _ = jax.lax.scan(body, (x, state), keys)
    return x


@pytest.mark.parametrize("alg", sorted(ALGORITHMS))
def test_all_algorithms_converge_homogeneous(alg):
    """Sanity: with iid data (ζ=0), deterministic grads, every algorithm
    drives the iterates to the optimum."""
    grad_fn, x_opt = _quadratic_problem(n=16, zeta=0.0)
    x0 = jnp.zeros((16, x_opt.shape[0]))
    x = _run(alg, grad_fn, x0, ring(16), alpha=0.05, beta=0.8, steps=2000)
    err = float(jnp.max(jnp.abs(x - x_opt[None])))
    # edm_ef's floor is the bf16 payload granularity (~0.4% of |x|), not 0
    tol = 6e-2 if alg == "edm_ef" else 1e-2
    assert err < tol, (alg, err)


def test_edm_eliminates_heterogeneity_bias():
    """Paper's central claim (Prop 2 contrast): with σ=0 and strong
    heterogeneity, DmSGD stalls at an O(α²ζ²/(1-λ)²) neighborhood while EDM
    converges to the exact optimum."""
    grad_fn, x_opt = _quadratic_problem(n=16, zeta=5.0)
    x0 = jnp.zeros((16, x_opt.shape[0]))
    topo = ring(16)
    x_edm = _run("edm", grad_fn, x0, topo, alpha=0.05, beta=0.9, steps=4000)
    x_dms = _run("dmsgd", grad_fn, x0, topo, alpha=0.05, beta=0.9, steps=4000)
    err_edm = float(jnp.mean(jnp.sum((x_edm - x_opt[None]) ** 2, -1)))
    err_dms = float(jnp.mean(jnp.sum((x_dms - x_opt[None]) ** 2, -1)))
    assert err_edm < 1e-6, err_edm
    assert err_dms > 50 * max(err_edm, 1e-12), (err_edm, err_dms)


def test_edm_beta0_equals_ed():
    """EDM with β=0 must reproduce ED/D² exactly (paper: 'when β = 0, the
    algorithm simplifies to the ED/D² method')."""
    grad_fn, x_opt = _quadratic_problem(n=8, zeta=1.0)
    x0 = jnp.ones((8, x_opt.shape[0]))
    topo = ring(8)
    x_a = _run("edm", grad_fn, x0, topo, alpha=0.03, beta=0.0, steps=50)
    x_b = _run("ed", grad_fn, x0, topo, alpha=0.03, beta=0.0, steps=50)
    np.testing.assert_allclose(x_a, x_b, rtol=1e-6)


def test_edm_mean_iterate_is_momentum_sgd():
    """Section 3.2: x̄(t+1) = x̄(t) − α m̄(t) — the average iterate follows
    plain momentum SGD regardless of the topology."""
    grad_fn, x_opt = _quadratic_problem(n=8, zeta=2.0)
    d = x_opt.shape[0]
    x = jnp.zeros((8, d))
    topo = ring(8)
    mix = make_mixer(topo)
    alpha, beta = 0.04, 0.9
    opt = make_optimizer("edm", alpha=alpha, beta=beta, mix=mix)
    state = opt.init(x)
    m_bar_ref = jnp.zeros(d)
    x_bar_ref = jnp.zeros(d)
    for _ in range(30):
        g = grad_fn(x)
        # reference: centralized momentum SGD on the averaged gradient of
        # *local* iterates (paper's m̄ recursion)
        m_bar_ref = beta * m_bar_ref + (1 - beta) * jnp.mean(g, 0)
        x_bar_ref = x_bar_ref - alpha * m_bar_ref
        x, state = opt.step(x, g, state)
        np.testing.assert_allclose(jnp.mean(x, 0), x_bar_ref, rtol=5e-4, atol=1e-5)


def test_edm_primal_recursion():
    """The 3-step form must satisfy the primal recursion (3.4):
    X(t+2) = W(2X(t+1) − X(t) − αM(t+1) + αM(t))."""
    grad_fn, x_opt = _quadratic_problem(n=8, zeta=1.0)
    d = x_opt.shape[0]
    topo = ring(8)
    mix = make_mixer(topo)
    alpha, beta = 0.05, 0.85
    opt = make_optimizer("edm", alpha=alpha, beta=beta, mix=mix)
    x0 = jax.random.normal(jax.random.PRNGKey(3), (8, d))
    state = opt.init(x0)
    xs, ms = [x0], []
    x = x0
    for t in range(6):
        g = grad_fn(x)
        m_new = beta * state["m"] + (1 - beta) * g
        ms.append(m_new)
        x, state = opt.step(x, g, state)
        xs.append(x)
    for t in range(0, 4):
        lhs = xs[t + 2]
        rhs = mix_shifts(topo, 2 * xs[t + 1] - xs[t] - alpha * ms[t + 1] + alpha * ms[t])
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


def test_metrics():
    x = jnp.stack([jnp.ones(3), -jnp.ones(3)])
    assert float(metrics.consensus_distance(x)) == pytest.approx(6.0)
    assert float(metrics.tree_sqnorm({"a": jnp.full(4, 2.0)})) == pytest.approx(16.0)


def test_edm_ef_compressed_gossip_recovers_floor():
    """Beyond-paper: naive bf16 gossip payloads blow up EDM's floor ~200×;
    edm_ef (error-feedback compression) recovers it to ≈ the f32 floor at
    half the wire bytes (EXPERIMENTS §Perf lever-safety table)."""
    grad_fn, x_opt = _quadratic_problem(n=16, zeta=2.0)
    x0 = jnp.zeros((16, x_opt.shape[0]))
    topo = ring(16)
    x_f32 = _run("edm", grad_fn, x0, topo, alpha=0.05, beta=0.9, steps=3000,
                 noise=0.05)
    x_ef = _run("edm_ef", grad_fn, x0, topo, alpha=0.05, beta=0.9, steps=3000,
                noise=0.05)
    err_f32 = float(jnp.mean(jnp.sum((x_f32 - x_opt[None]) ** 2, -1)))
    err_ef = float(jnp.mean(jnp.sum((x_ef - x_opt[None]) ** 2, -1)))
    # within one order of the f32 floor (vs ~200x for naive bf16 gossip)
    assert err_ef < 10 * max(err_f32, 1e-9) + 5e-3, (err_f32, err_ef)
