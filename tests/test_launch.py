"""Launcher-level tests: dry-run CLI, ppermute gossip engine on a multi-device
host mesh, training CLI — run in subprocesses so XLA_FLAGS device-count
settings cannot leak into this test process."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=560, env=None):
    return subprocess.run([sys.executable] + args, cwd=REPO, env=env or ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_dryrun_cli_lowers_and_reports():
    """Deliverable (e): the dry-run CLI lowers+compiles a full-size arch on
    the 16×16 production mesh and emits roofline terms."""
    r = _run(["-m", "repro.launch.dryrun", "--arch", "smollm_360m",
              "--shape", "long_500k", "--mesh", "single", "--force",
              "--tag", "citest"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "[OK ]" in r.stdout
    path = os.path.join(REPO, "experiments", "dryrun",
                        "smollm_360m__long_500k__single_citest.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["ok"] and rec["mesh"] == "16x16"
    rf = rec["roofline"]
    assert rf["t_memory_s"] > 0 and rf["bottleneck"] in (
        "compute", "memory", "collective")


def test_dryrun_existing_artifacts_complete():
    """All 80 baseline combos must exist on disk and be ok (the sweep is the
    standing proof; this guards against regressions deleting/corrupting it)."""
    base = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(base):
        pytest.skip("sweep artifacts not present")
    n_ok = 0
    for name in os.listdir(base):
        parts = name[:-5].split("__")
        if len(parts) != 3 or parts[2] not in ("single", "multi"):
            continue  # tagged perf variants
        with open(os.path.join(base, name)) as f:
            rec = json.load(f)
        assert rec.get("ok"), name
        n_ok += 1
    if n_ok == 0:
        # only tagged one-off artifacts on disk (e.g. the citest record the
        # CLI test above writes) — the 80-combo sweep was never run here
        pytest.skip("sweep artifacts not present")
    assert n_ok == 80, n_ok


def test_ppermute_engine_multi_device():
    """mix_ppermute == dense-W oracle on an 8-device host mesh, and the HLO
    contains literal collective-permute ops (the paper's gossip primitive)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import ring
from repro.core.mixing import mix_dense, mix_ppermute
mesh = jax.make_mesh((8,), ("agents",))
topo = ring(8)
x = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
got = jax.jit(lambda t: mix_ppermute(topo, mesh, "agents", t))(x)
want = mix_dense(topo, x)
np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                           rtol=2e-5, atol=1e-6)
hlo = jax.jit(lambda t: mix_ppermute(topo, mesh, "agents", t)) \\
    .lower(x).compile().as_text()
assert hlo.count("collective-permute(") >= 2, "expected explicit permutes"
print("PPERMUTE_OK")
"""
    r = _run(["-c", code])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "PPERMUTE_OK" in r.stdout


def test_train_cli_smoke():
    r = _run(["-m", "repro.launch.train", "--arch", "smollm_360m", "--smoke",
              "--steps", "3", "--agents", "4", "--seq", "16"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "loss=" in r.stdout


def test_serve_cli_smoke():
    r = _run(["-m", "repro.launch.serve", "--arch", "smollm_360m", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--new-tokens", "4"])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "generated" in r.stdout
