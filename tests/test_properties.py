"""Property-based (hypothesis) tests on system invariants.

Requires the optional ``test`` extra (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

# the hypothesis sweeps are the slow tail of the suite — tier-1 CI
# deselects them (-m "not slow"); the slow-tests job runs them.
pytestmark = pytest.mark.slow

from repro.configs.base import ModelConfig
from repro.core import exp_graph, hierarchical, make_mixer, ring, torus2d
from repro.core.mixing import mix_dense, mix_shifts

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# gossip invariants
# ---------------------------------------------------------------------------

def _topo_strategy(draw):
    kind = draw(st.sampled_from(["ring", "exp", "torus", "hier"]))
    if kind == "ring":
        return ring(draw(st.sampled_from([2, 3, 8, 17, 32])))
    if kind == "exp":
        return exp_graph(draw(st.sampled_from([4, 8, 16, 32])))
    if kind == "torus":
        p = draw(st.sampled_from([2, 4]))
        d = draw(st.sampled_from([4, 8]))
        return torus2d(p, d)
    p = draw(st.sampled_from([2, 4]))
    d = draw(st.sampled_from([4, 8]))
    return hierarchical(p, d, c=draw(st.sampled_from([0.3, 0.5, 0.8])))


topos = st.composite(_topo_strategy)()


@settings(max_examples=25, deadline=None)
@given(topo=topos, seed=st.integers(0, 2**31 - 1))
def test_gossip_preserves_mean_and_contracts(topo, seed):
    """For any shipped topology: (1) W is doubly stochastic → mean preserved;
    (2) consensus distance never increases (contraction of P_I W)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (topo.n_agents, 6))
    mixed = mix_shifts(topo, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(mixed, 0)),
                               np.asarray(jnp.mean(x, 0)), rtol=2e-5,
                               atol=1e-5)
    def cons(z):
        return float(jnp.sum((z - jnp.mean(z, 0, keepdims=True)) ** 2))
    assert cons(mixed) <= cons(x) * (1 + 1e-5)


@settings(max_examples=25, deadline=None)
@given(topo=topos, seed=st.integers(0, 2**31 - 1))
def test_shift_engine_equals_dense_engine(topo, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (topo.n_agents, 3, 4))
    np.testing.assert_allclose(np.asarray(mix_shifts(topo, x)),
                               np.asarray(mix_dense(topo, x)),
                               rtol=3e-5, atol=3e-6)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), steps=st.integers(3, 12),
       beta=st.sampled_from([0.0, 0.5, 0.9]), seed=st.integers(0, 2**31 - 1))
def test_edm_mean_invariant_property(n, steps, beta, seed):
    """x̄(t+1) = x̄(t) − α m̄(t) for arbitrary gradient streams — the paper's
    §3.2 identity, which must hold exactly for ANY gossip matrix."""
    from repro.core import make_optimizer
    topo = ring(n)
    mix = make_mixer(topo)
    alpha = 0.07
    opt = make_optimizer("edm", alpha=alpha, beta=beta, mix=mix)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, 5))
    state = opt.init(x)
    m_bar = jnp.zeros(5)
    x_bar = jnp.mean(x, 0)
    for t in range(steps):
        key, kg = jax.random.split(key)
        g = jax.random.normal(kg, (n, 5))
        m_bar = beta * m_bar + (1 - beta) * jnp.mean(g, 0)
        x_bar = x_bar - alpha * m_bar
        x, state = opt.step(x, g, state)
        np.testing.assert_allclose(np.asarray(jnp.mean(x, 0)),
                                   np.asarray(x_bar), rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch == dense per-token oracle (when capacity is sufficient)
# ---------------------------------------------------------------------------

def _moe_dense_oracle(p, cfg, x, eps):
    """Compute every expert for every token, combine by router weights."""
    from repro.models.layers import rms_norm, swiglu
    from repro.models.moe import _route
    B, S, d = x.shape
    h = rms_norm(x, p["ln"], eps)
    flat = h.reshape(-1, d)
    w, idx, aux = _route(flat @ p["router"].astype(flat.dtype),
                         cfg.experts_per_token)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", flat, p["w_gate"]))
    u = jnp.einsum("td,edf->tef", flat, p["w_up"])
    all_out = jnp.einsum("tef,efd->ted", g * u, p["w_down"])  # (T, E, d)
    sel = jnp.take_along_axis(all_out, idx[..., None], axis=1)  # (T, k, d)
    comb = jnp.sum(sel * w[..., None].astype(sel.dtype), axis=1)
    y = comb.reshape(B, S, d)
    if "shared" in p:
        sp = p["shared"]
        y = y + swiglu(h, sp["w_gate"], sp["w_up"], sp["w_down"])
    return x + y


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_exp=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), shared=st.booleans())
def test_moe_dispatch_equals_dense_oracle(seed, n_exp, k, shared):
    from repro.models.moe import apply_moe, init_moe
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=48, vocab_size=64,
                      n_experts=n_exp, experts_per_token=k,
                      n_shared_experts=1 if shared else 0,
                      capacity_factor=float(n_exp), dtype="float32")
    p = init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 32))
    got, aux = apply_moe(p, cfg, x, 1e-6)
    want = _moe_dense_oracle(p, cfg, x, 1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    assert jnp.isfinite(aux)


# ---------------------------------------------------------------------------
# Mamba chunked scan == sequential oracle
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.sampled_from([8, 16, 64]),
       chunk=st.sampled_from([4, 8, 16]))
def test_chunked_scan_equals_sequential(seed, S, chunk):
    from repro.models.mamba import _chunked_scan, ssm_scan_ref
    B, di, s = 2, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.random.uniform(ks[0], (B, S, di, s), minval=0.3, maxval=0.99)
    b = jax.random.normal(ks[1], (B, S, di, s))
    h0 = jax.random.normal(ks[2], (B, di, s))
    hs_c, hT_c = _chunked_scan(a, b, h0, chunk)
    hs_r, hT_r = ssm_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs_c), np.asarray(hs_r),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hT_c), np.asarray(hT_r),
                               rtol=2e-4, atol=2e-5)


def test_mamba_train_decode_agree():
    """Running the block step-by-step in decode mode reproduces the train-mode
    (chunked-scan) outputs — the SSM serving path is the same function."""
    from repro.models.mamba import apply_mamba, init_mamba, init_ssm_cache
    cfg = ModelConfig(name="m", family="ssm", n_layers=1, d_model=32,
                      ssm_state=4, ssm_conv=4, ssm_expand=2, ssm_dt_rank=8,
                      vocab_size=64, dtype="float32")
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    y_train, _ = apply_mamba(p, cfg, x, mode="train", chunk=5)
    cache = init_ssm_cache(cfg, 2)
    outs = []
    for t in range(10):
        y_t, cache = apply_mamba(p, cfg, x[:, t:t + 1], mode="decode",
                                 cache=cache)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=5e-4, atol=5e-5)


# ---------------------------------------------------------------------------
# config system invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(arch=st.sampled_from(
    ["pixtral_12b", "qwen3_moe_235b_a22b", "falcon_mamba_7b", "qwen1_5_110b",
     "whisper_small", "smollm_360m", "starcoder2_7b", "jamba_1_5_large_398b",
     "deepseek_moe_16b", "qwen3_14b"]))
def test_layer_kinds_consistent_with_period(arch):
    from repro.configs import get_config
    from repro.configs.base import block_period, layer_kinds
    cfg = get_config(arch)
    kinds = layer_kinds(cfg)
    p = block_period(cfg)
    assert cfg.n_layers % p == 0
    for i, kd in enumerate(kinds):
        assert kd == kinds[i % p]
    assert cfg.n_active_params() <= cfg.n_params()
