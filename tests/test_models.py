"""Per-architecture smoke tests: reduced same-family variant, one forward /
train step on CPU, asserting output shapes + finite values; plus
prefill↔decode consistency for the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, RunConfig
from repro.models import build_model, input_specs, batch_specs


def _make_batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 2)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.family in ("vlm", "encdec"):
        batch["frontend"] = jax.random.normal(
            ks[1], (B, cfg.n_frontend_tokens, cfg.d_model),
            dtype=jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _make_batch(cfg, key)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    # rough sanity: initial loss ≈ ln(vocab)
    assert 0.2 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), arch
    # at least one nonzero gradient per layer stack
    assert any(float(jnp.max(jnp.abs(l))) > 0 for l in leaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_improves(arch):
    """3 SGD steps on a fixed batch reduce the loss (learning happens)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _make_batch(cfg, jax.random.PRNGKey(2))

    @jax.jit
    def step(params):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                              params, grads)
        return params, loss

    losses = []
    for _ in range(4):
        params, loss = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode_step over a cached prefix must reproduce the prefill logits of
    the full sequence (the serving path computes the same function)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 2, 16
    batch = _make_batch(cfg, jax.random.PRNGKey(4), B=B, S=S + 1)
    tokens = batch["tokens"]

    # full prefill over S+1 tokens → logits at the last position
    logits_full, _ = model.prefill(params, {**batch, "tokens": tokens})

    # prefill S tokens, then one decode step with token S
    pre = {**batch, "tokens": tokens[:, :S]}
    _, caches = model.prefill(params, pre)
    # absolute position of the next token (frontend tokens occupy the prefix
    # of the VLM stream; encdec cross-caches must not grow)
    n_front = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
    L0 = S + n_front

    def grow(path, c):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and c.ndim >= 4 and c.shape[-3] == L0:
            pad = jnp.zeros(c.shape[:-3] + (1,) + c.shape[-2:], c.dtype)
            return jnp.concatenate([c, pad], axis=-3)
        return c

    caches = jax.tree_util.tree_map_with_path(grow, caches)
    logits_dec, _ = model.decode_step(params, caches, tokens[:, S:S + 1],
                                      jnp.asarray(L0, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_full, np.float32), np.asarray(logits_dec, np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_no_allocation(arch, shape):
    """input_specs must be pure ShapeDtypeStructs for the FULL config."""
    from repro.configs import get_config, INPUT_SHAPES
    cfg = get_config(arch)
    run = INPUT_SHAPES[shape]
    specs = input_specs(cfg, run, agent_axis=16 if run.mode == "train" else None)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_moe_routing_is_sparse():
    """Compiled MoE FLOPs must scale with top-k, not n_experts (honest
    roofline check at smoke scale)."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("deepseek_moe_16b"),
                              capacity_factor=1.25)  # production capacity
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _make_batch(cfg, jax.random.PRNGKey(1), B=2, S=64)
    c = jax.jit(model.loss).lower(params, batch).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict]
        ca = ca[0]
    fl = ca["flops"]
    # dense-all-experts lower bound: E/k ratio would inflate flops ~2x+
    T = 2 * 64
    d, ff, E, k = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.experts_per_token
    dense_all = 2 * T * d * ff * 3 * E * cfg.n_layers
    assert fl < 0.7 * dense_all, (fl, dense_all)
