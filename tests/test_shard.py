"""Shard-resident gossip (DESIGN §7): FSDP-sharded bus + shard-local
ppermute engine.

* layout math: ``shards=S`` rounds rows to ``block_rows·S`` so each shard's
  row block is griddable; the layout cache keys on the shard count;
* config resolution: ``packed_bus`` composes with ``agents="pod"`` and
  ``state_specs`` emits the ``P('pod', 'data')`` row-sharded bus specs;
* sharded ``ppermute == dense`` — on a real 2-pod × 4-shard (and 4 × 2)
  host mesh, the sharded engine matches both the plain dense oracle and the
  shard-resident all-gather oracle (``mix_dense_sharded``) across
  topologies × schedules × {fused, unfused} (8-device subprocess);
* HLO acceptance for the composed ``agents="pod"`` + packed-bus train step
  (sync and delayed overlap, fused and unfused): exactly one bus-shaped
  ``collective-permute`` per nonzero gossip term, and every one of them
  carries the **shard-local** ``(1, rows/S, 128)`` payload — an all-gather
  feeding a gossip permute would make the operand full-rows, so the shape
  pin is the "no all-gather ever precedes a gossip permute" guarantee in
  operand-dependency form (wire bytes per device drop by exactly S);
* sharding-independent checkpoints: save sharded → load gathered and
  vice versa (different shard counts pad rows differently; the on-disk
  logical tree is identical).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.core import bus
from repro.train import state_specs, use_packed_bus

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(REPO, "src")
       + (os.pathsep + os.environ["PYTHONPATH"]
          if os.environ.get("PYTHONPATH") else "")}


def _tree(A, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return {
        "emb": jax.random.normal(ks[0], (A, 17, 9)),
        "w": jax.random.normal(ks[1], (A, 33)),
        "head": jax.random.normal(ks[2], (A, 129)),
    }


# ---------------------------------------------------------------------------
# layout: shard rounding + cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 4])
def test_layout_shard_rounding(shards):
    tree = _tree(2)
    layout = bus.make_layout(tree, block_rows=8, shards=shards)
    assert layout.shards == shards
    assert layout.rows % (8 * shards) == 0
    assert layout.shard_rows * shards == layout.rows
    assert layout.shard_rows % layout.block_rows == 0
    # logical content is shard-count-independent: pack under any shard
    # layout and the logical elements land at the same offsets
    packed = bus.pack_tree(layout, tree)
    assert packed.shape == (2, layout.rows, 128)
    back = bus.unpack_tree(layout, packed)
    for w, g in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_layout_cache_keys_on_shards():
    t = _tree(4)
    l1 = bus.make_layout(t, block_rows=8, shards=1)
    l2 = bus.make_layout(t, block_rows=8, shards=4)
    assert l1 is not l2
    assert bus.make_layout(_tree(4, key=7), block_rows=8, shards=4) is l2
    # a sharded layout never has FEWER rows than the unsharded one
    assert l2.rows >= l1.rows


# ---------------------------------------------------------------------------
# config resolution + specs
# ---------------------------------------------------------------------------

def test_packed_bus_composes_with_pod_agents():
    assert use_packed_bus(RunConfig(algorithm="edm",
                                    gossip_engine="ppermute", agents="pod"))
    assert use_packed_bus(RunConfig(algorithm="edm", packed_bus=True,
                                    agents="pod"))
    with pytest.raises(AssertionError):
        use_packed_bus(RunConfig(algorithm="dsgd", packed_bus=True,
                                 agents="pod"))


def test_state_specs_pod_bus_row_sharded():
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.train import init_state

    model = build_model(get_smoke_config("smollm_360m"))
    run = RunConfig(algorithm="edm", agents="pod", packed_bus=True,
                    overlap="delayed", remat=False)
    specs = state_specs(model, run, multi_pod=True)
    assert specs["params"] == P("pod", "data")
    assert specs["opt"]["m"] == P("pod", "data")
    assert specs["pipeline"]["slot"] == P(None, "pod", "data")
    assert specs["pipeline"]["parity"] == P()
    # structures line up with the real state (tree.map raises on mismatch)
    state = jax.eval_shape(
        lambda: init_state(model, run, 2, jax.random.PRNGKey(0), shards=4))
    jax.tree.map(lambda sds, sp: None, state, specs,
                 is_leaf=lambda x: isinstance(x, P))
    # single-pod fallback replicates the agent axis but keeps FSDP rows
    assert state_specs(model, run, multi_pod=False)["params"] == \
        P(None, "data")


def test_gossip_mesh_sharded_needs_devices():
    from repro.launch.mesh import make_gossip_mesh

    n_dev = jax.device_count()
    with pytest.raises(AssertionError):
        make_gossip_mesh(n_dev, pods=n_dev, shards=8)  # 8× too many
    with pytest.raises(AssertionError):
        make_gossip_mesh(4, pods=2, shards=2)  # pods must equal n_agents


# ---------------------------------------------------------------------------
# checkpoint: sharding-independence at the layout level (single device —
# a shards=4 layout pads differently from shards=1, yet the on-disk
# logical tree is identical and loads into either)
# ---------------------------------------------------------------------------

def test_checkpoint_shard_layout_independent(tmp_path):
    from repro.train import checkpoint

    tree = _tree(4)
    l_flat = bus.make_layout(tree, block_rows=8, shards=1)
    l_shard = bus.make_layout(tree, block_rows=8, shards=4)
    assert l_flat.rows != l_shard.rows or l_flat is not l_shard
    packed_s = bus.pack_tree(l_shard, tree)

    p = str(tmp_path / "sharded.npz")
    checkpoint.save(p, packed_s, layout=l_shard)
    # sharded-layout save restores into the flat layout...
    flat_bus = checkpoint.load(p, jnp.zeros((4, l_flat.rows, 128)),
                               layout=l_flat)
    np.testing.assert_array_equal(np.asarray(bus.unpack_tree(l_flat,
                                                             flat_bus)["w"]),
                                  np.asarray(tree["w"]))
    # ...and a flat save restores into the sharded layout
    p2 = str(tmp_path / "flat.npz")
    checkpoint.save(p2, bus.pack_tree(l_flat, tree), layout=l_flat)
    shard_bus = checkpoint.load(p2, jnp.zeros_like(packed_s), layout=l_shard)
    np.testing.assert_array_equal(np.asarray(shard_bus),
                                  np.asarray(packed_s))


# ---------------------------------------------------------------------------
# sharded ppermute == dense + HLO + checkpoint on a real pods × shards mesh
# (8-device subprocess)
# ---------------------------------------------------------------------------

_SHARD_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (RoundRobinExp, StaticSchedule, exp_graph,
                        make_mixer, make_schedule_mixer, mix_dense,
                        mix_dense_sharded, ring)
from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh

for A, S in ((2, 4), (4, 2)):
    mesh = make_gossip_mesh(A, pods=A, shards=S)
    assert gossip_agent_axes(mesh, sharded=True) == "pod"
    rows = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (A, rows, 128))
    xs = jax.device_put(x, NamedSharding(mesh, P("pod", "data")))
    for topo in (ring(A), exp_graph(A)):
        for fused in (False, True):
            mix = make_mixer(topo, "ppermute", mesh=mesh, agent_axes="pod",
                             use_fused_kernel=fused, shard_axes="data")
            got = np.asarray(jax.jit(mix)(xs))
            want = np.asarray(mix_dense(topo, x))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                err_msg=f"{A}x{S} {topo.name} fused={fused}")
            oracle = np.asarray(mix_dense_sharded(topo, mesh, "pod",
                                                  "data", xs))
            np.testing.assert_allclose(oracle, want, rtol=1e-5, atol=1e-6,
                err_msg=f"sharded-oracle {A}x{S} {topo.name}")
    for sched in (StaticSchedule(ring(A)), RoundRobinExp(A)):
        for fused in (False, True):
            mix = make_schedule_mixer(sched, "ppermute", mesh=mesh,
                                      agent_axes="pod", shard_axes="data",
                                      use_fused_kernel=fused)
            for s in range(sched.period):
                got = np.asarray(jax.jit(lambda t, s=s: mix(t, step=s))(xs))
                want = np.asarray(mix_dense(sched.round(s), x))
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                    err_msg=f"{A}x{S} {sched.name} step={s} fused={fused}")
    print(f"SHARD_EQUIV_OK {A}x{S}")

# --- composed agents="pod" train step: HLO + trajectory + checkpoint -------
from repro.configs.base import ModelConfig, RunConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train import (build_train_step, bus_layout_for, checkpoint,
                         init_state, make_gossip_schedule, state_specs)

cfg = ModelConfig(name="shard-tiny", family="dense", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32")
model = build_model(cfg)
A, S = 2, 4
mesh = make_gossip_mesh(A, pods=A, shards=S)
batch = SyntheticLM(vocab_size=64, seq_len=8,
                    n_agents=A).sample(jax.random.PRNGKey(1), 1)

def build(overlap, fused, sharded=True):
    run = RunConfig(global_batch=A, seq_len=8, algorithm="edm", alpha=0.1,
                    agents="pod" if sharded else "data",
                    gossip_engine="ppermute", packed_bus=True,
                    overlap=overlap, remat=False)
    sched = make_gossip_schedule(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0),
                       shards=S if sharded else 1)
    if sharded:
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                                 state_specs(model, run, multi_pod=True),
                                 is_leaf=lambda x: isinstance(x, P))
        state = jax.tree.map(jax.device_put, state, shardings)
        step = build_train_step(model, run, sched, mesh=mesh,
                                agent_axes="pod", shard_axes="data",
                                use_fused_kernel=fused)
    else:
        m1 = make_gossip_mesh(A)
        step = build_train_step(model, run, sched, mesh=m1,
                                agent_axes=gossip_agent_axes(m1),
                                use_fused_kernel=fused)
    return run, state, jax.jit(step, donate_argnums=(0,))

layout = bus_layout_for(model, A, shards=S)
n_perm = sum(1 for t in ring(A).terms if t.shift != 0)
for overlap in ("off", "delayed"):
    for fused in (False, True):
        run, state, step = build(overlap, fused)
        hlo = step.lower(state, batch).compile().as_text()
        # bus-shaped permutes: f32[a, r, 128].  The shape pin IS the
        # no-all-gather guarantee: a gathered operand would be full-rows.
        perms = re.findall(
            r"= f32\\[(\\d+),(\\d+),128\\]\\S* collective-permute\\(", hlo)
        assert len(perms) == n_perm, (overlap, fused, perms, n_perm)
        for a, r in perms:
            assert int(r) == layout.shard_rows, \
                (overlap, fused, r, layout.shard_rows, layout.rows)
        print(f"SHARD_HLO_OK overlap={overlap} fused={fused} "
              f"rows_local={layout.shard_rows} rows={layout.rows}")

# sharded trajectory == unsharded trajectory (same model/data/init)
for fused in (False, True):
    _, s_sh, st_sh = build("off", fused)
    _, s_un, st_un = build("off", fused, sharded=False)
    for _ in range(3):
        s_sh, m_sh = st_sh(s_sh, batch)
        s_un, m_un = st_un(s_un, batch)
        np.testing.assert_allclose(float(m_sh["loss"]), float(m_un["loss"]),
                                   rtol=1e-5, atol=1e-6)
    from repro.core import bus as parambus
    got = parambus.unpack_tree(bus_layout_for(model, A, shards=S),
                               jax.device_get(s_sh["params"]))
    want = parambus.unpack_tree(bus_layout_for(model, A),
                                jax.device_get(s_un["params"]))
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)
print("SHARD_TRAJ_OK")

# checkpoint: save the SHARDED run, restore into a GATHERED (shards=1)
# data-mode run and vice versa — trajectories continue identically
import tempfile
run_sh, s_sh, st_sh = build("off", False)
for _ in range(2):
    s_sh, _ = st_sh(s_sh, batch)
run_un, s_un, st_un = build("off", False, sharded=False)
for _ in range(2):
    s_un, _ = st_un(s_un, batch)
with tempfile.TemporaryDirectory() as d:
    p = os.path.join(d, "sh.npz")
    checkpoint.save_state(p, s_sh, layout=bus_layout_for(model, A, shards=S))
    like = build("off", False, sharded=False)[1]
    restored = checkpoint.load_state(p, like,
                                     layout=bus_layout_for(model, A))
    np.testing.assert_allclose(np.asarray(restored["params"]),
                               np.asarray(jax.device_get(s_un["params"])),
                               rtol=1e-5, atol=1e-6)
    p2 = os.path.join(d, "un.npz")
    checkpoint.save_state(p2, s_un, layout=bus_layout_for(model, A))
    like_sh = build("off", False)[1]
    restored_sh = checkpoint.load_state(
        p2, jax.device_get(like_sh),
        layout=bus_layout_for(model, A, shards=S))
    np.testing.assert_allclose(
        np.asarray(restored_sh["params"]),
        np.asarray(jax.device_get(s_sh["params"])), rtol=1e-5, atol=1e-6)
print("SHARD_CKPT_OK")
"""


def test_sharded_gossip_subprocess():
    r = subprocess.run([sys.executable, "-c", _SHARD_CODE], cwd=REPO,
                       env=ENV, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    for marker in ("SHARD_EQUIV_OK 2x4", "SHARD_EQUIV_OK 4x2",
                   "SHARD_HLO_OK overlap=off fused=False",
                   "SHARD_HLO_OK overlap=delayed fused=True",
                   "SHARD_TRAJ_OK", "SHARD_CKPT_OK"):
        assert marker in r.stdout, (marker, r.stdout[-2000:])
