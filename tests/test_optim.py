"""LR-schedule substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import constant, cosine, linear_warmup, scale_grads, warmup_cosine


def test_warmup_ramps_then_cosine_decays():
    sch = warmup_cosine(10, 100)
    vals = [float(sch(jnp.asarray(s))) for s in range(0, 101, 10)]
    assert vals[0] < 0.2                        # warmup start
    assert abs(vals[1] - 1.0) < 0.05            # warmup end ≈ base
    assert vals[-1] == pytest.approx(0.1, abs=1e-5)  # cosine floor
    assert all(a >= b - 1e-6 for a, b in zip(vals[1:], vals[2:]))  # decay


def test_constant_and_warmup():
    assert float(constant(0.5)(jnp.asarray(123))) == 0.5
    w = linear_warmup(4)
    np.testing.assert_allclose(
        [float(w(jnp.asarray(s))) for s in range(5)],
        [0.25, 0.5, 0.75, 1.0, 1.0])


def test_scale_grads_tree():
    g = {"a": jnp.ones((2, 3)), "b": jnp.full((4,), 2.0, jnp.bfloat16)}
    out = scale_grads(g, jnp.asarray(0), cosine(10, base=2.0, floor=0.0))
    np.testing.assert_allclose(np.asarray(out["a"], np.float32), 2.0)
    assert out["b"].dtype == jnp.bfloat16


def test_trainer_with_schedule_runs():
    from repro.configs.base import ModelConfig, RunConfig
    from repro.data import SyntheticLM
    from repro.models import build_model
    from repro.train import build_train_step, init_state, make_topology
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                      dtype="float32")
    model = build_model(cfg)
    run = RunConfig(global_batch=4, seq_len=8, algorithm="edm", alpha=0.1,
                    beta=0.9, remat=False, warmup_steps=2, total_steps=10)
    topo = make_topology(run, 4)
    state = init_state(model, run, 4, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, topo))
    data = SyntheticLM(vocab_size=128, seq_len=8, n_agents=4)
    for t in range(3):
        state, m = step(state, data.sample(jax.random.PRNGKey(t), 1))
    assert jnp.isfinite(m["loss"])
