"""End-to-end system tests: decentralized trainer, serving engine,
checkpointing, sharding-spec coherence, and HLO analysis utilities."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, RunConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.serve import build_serve_step, greedy_generate
from repro.train import (build_train_step, checkpoint, init_state,
                         make_topology, state_specs)

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                   dtype="float32")


def _run_cfg(**kw):
    base = dict(global_batch=8, seq_len=16, algorithm="edm", alpha=2e-2,
                beta=0.9, topology="ring", remat=False)
    base.update(kw)
    return RunConfig(**base)


@pytest.mark.parametrize("algorithm", ["edm", "ed", "dsgd", "dmsgd", "dsgt",
                                       "dsgt_hb", "decentlam", "qg"])
def test_decentralized_train_step_all_algorithms(algorithm):
    """One jitted decentralized train step per algorithm: finite metrics,
    params updated, consensus stays bounded."""
    run = _run_cfg(algorithm=algorithm)
    model = build_model(TINY)
    A = 4
    topo = make_topology(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, topo))
    data = SyntheticLM(vocab_size=TINY.vocab_size, seq_len=run.seq_len,
                       n_agents=A, phi=0.5)
    batch = data.sample(jax.random.PRNGKey(1), run.global_batch // A)
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["consensus"])
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state["params"], state2["params"])
    assert max(jax.tree.leaves(moved)) > 0


def test_training_reduces_loss_multi_step():
    """30 EDM steps on heterogeneous synthetic LM data reduce the loss."""
    run = _run_cfg(alpha=0.3, seq_len=32)
    model = build_model(TINY)
    A = 4
    topo = make_topology(run, A)
    state = init_state(model, run, A, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, topo))
    data = SyntheticLM(vocab_size=TINY.vocab_size, seq_len=run.seq_len,
                       n_agents=A, phi=0.2)
    key = jax.random.PRNGKey(2)
    losses = []
    for t in range(30):
        key, kd = jax.random.split(key)
        state, m = step(state, data.sample(kd, 2))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_edm_consensus_contracts_vs_dsgd():
    """Bias correction: under heterogeneous data the EDM consensus distance
    stays of the same order as DSGD's while the mean loss tracks lower/equal
    (sanity of the integrated trainer, not a theorem check)."""
    model = build_model(TINY)
    A = 4
    data = SyntheticLM(vocab_size=TINY.vocab_size, seq_len=16, n_agents=A,
                       phi=0.1)
    finals = {}
    for alg in ("edm", "dsgd"):
        run = _run_cfg(algorithm=alg, alpha=5e-2)
        topo = make_topology(run, A)
        state = init_state(model, run, A, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(model, run, topo))
        key = jax.random.PRNGKey(3)
        for _ in range(15):
            key, kd = jax.random.split(key)
            state, m = step(state, data.sample(kd, 2))
        finals[alg] = float(m["loss"])
    assert finals["edm"] <= finals["dsgd"] + 0.5, finals


def test_greedy_generate_shapes_and_determinism():
    cfg = get_smoke_config("smollm_360m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size)}
    out1 = greedy_generate(model, params, batch, n_steps=5)
    out2 = greedy_generate(model, params, batch, n_steps=5)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(out1, out2)
    assert jnp.all((out1 >= 0) & (out1 < cfg.vocab_size))


def test_sliding_window_decode_matches_full_within_window():
    """With window W ≥ context length, windowed decode == full decode."""
    cfg = dataclasses.replace(TINY, n_layers=2)
    S = 12
    m_full = build_model(cfg)
    m_win = build_model(cfg, decode_window=16)  # ring cache 16 > S
    params = m_full.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    lf, _ = m_full.prefill(params, {"tokens": toks})
    lw, _ = m_win.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw), rtol=1e-5,
                               atol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)},
            "d": [jnp.zeros(2), jnp.full((1, 1), 7.0)]}
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, tree)
    back = checkpoint.load(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_state_specs_match_state_structure():
    """Sharding-spec trees must be congruent with the actual state pytrees
    for every algorithm (the dry-run relies on this)."""
    model = build_model(TINY)
    for alg in ("edm", "dsgd", "dmsgd", "dsgt", "dsgt_hb", "decentlam", "qg"):
        run = _run_cfg(algorithm=alg)
        state = jax.eval_shape(
            lambda: init_state(model, run, 4, jax.random.PRNGKey(0)))
        specs = state_specs(model, run, multi_pod=False)
        # tree.map raises on structure mismatch
        jax.tree.map(lambda sds, sp: None, state, specs,
                     is_leaf=lambda x: isinstance(x, P))


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes, count_collectives
    hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[1,128]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = bf16[256]{0} all-reduce(bf16[256]{0} %y), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[64]{0} collective-permute(f32[64]{0} %z), source_target_pairs={{0,1},{1,0}}
"""
    c = collective_bytes(hlo)
    assert c["all-gather"] == pytest.approx(16 * 128 * 4 * 15 / 16)
    assert c["all-reduce"] == pytest.approx(2 * 256 * 2 * 3 / 4)
    assert c["collective-permute"] == pytest.approx(64 * 4)
    counts = count_collectives(hlo)
    assert counts == {"all-gather": 1, "all-reduce": 1,
                      "collective-permute": 1}


def test_gossip_lowers_to_collective_permute():
    """The production claim: ring gossip on a sharded agent axis compiles to
    collective-permute ops, NOT all-reduce/all-gather."""
    from repro.core import make_mixer, ring
    devs = jax.devices()
    if len(devs) < 2:
        # single CPU device: verify on an unsharded axis that rolls appear
        mix = make_mixer(ring(4))
        hlo = jax.jit(mix).lower(jnp.zeros((4, 8))).as_text()
        assert "slice" in hlo or "concatenate" in hlo  # roll lowering
        return
