"""Training launcher.

On real hardware this runs the decentralized EDM trainer on the production
mesh; on this CPU container it runs the same program on a 1×1 mesh with the
agent axis unsharded (reduced configs), which is how the examples and tests
exercise it.

  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --smoke \
      --steps 20 --agents 4 --algorithm edm
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import RunConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train import build_train_step, checkpoint, init_state, make_topology


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--per-agent-batch", type=int, default=1)
    ap.add_argument("--algorithm", default="edm")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod count for torus/hier topologies")
    ap.add_argument("--gossip-engine", default="shifts",
                    choices=["dense", "shifts", "ppermute"],
                    help="mixing engine; ppermute needs one device per agent "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N on CPU)")
    ap.add_argument("--fused-kernel", action="store_true",
                    help="fused Pallas EDM update + gossip combine")
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--beta", type=float, default=0.9)
    ap.add_argument("--phi", type=float, default=0.2,
                    help="Dirichlet heterogeneity of the token streams")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    run = RunConfig(global_batch=args.agents * args.per_agent_batch,
                    seq_len=args.seq, algorithm=args.algorithm,
                    alpha=args.alpha, beta=args.beta, topology=args.topology,
                    gossip_engine=args.gossip_engine, remat=False)
    topo = make_topology(run, args.agents, pods=args.pods)
    mesh = agent_axes = None
    if args.gossip_engine == "ppermute":
        from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
        mesh = make_gossip_mesh(args.agents, pods=args.pods)
        agent_axes = gossip_agent_axes(mesh)
    print(f"arch={cfg.name} ({cfg.n_params()/1e6:.1f}M params) "
          f"agents={args.agents} topo={args.topology} λ={topo.lam():.4f} "
          f"alg={args.algorithm} engine={args.gossip_engine}"
          f"{' +fused' if args.fused_kernel else ''}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       n_agents=args.agents, phi=args.phi)

    def sample(key):
        b = data.sample(key, args.per_agent_batch)
        if cfg.family in ("vlm", "encdec"):
            import jax.numpy as jnp
            b["frontend"] = jax.random.normal(
                jax.random.fold_in(key, 1),
                (args.agents, args.per_agent_batch, cfg.n_frontend_tokens,
                 cfg.d_model), dtype=jnp.dtype(cfg.dtype))
        return b

    state = init_state(model, run, args.agents, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, run, topo,
                                    use_fused_kernel=args.fused_kernel,
                                    mesh=mesh, agent_axes=agent_axes))
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for t in range(args.steps):
        key, kd = jax.random.split(key)
        state, m = step(state, sample(kd))
        if t % 5 == 0 or t == args.steps - 1:
            print(f"step {t:4d} loss={float(m['loss']):.4f} "
                  f"consensus={float(m['consensus']):.2e} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state["params"])
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
