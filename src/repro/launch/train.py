"""Training launcher.

On real hardware this runs the decentralized EDM trainer on the production
mesh; on this CPU container it runs the same program on a 1×1 mesh with the
agent axis unsharded (reduced configs), which is how the examples and tests
exercise it.

  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --smoke \
      --steps 20 --agents 4 --algorithm edm
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import RunConfig
from repro.data import SyntheticLM
from repro.launch.flags import add_run_flags, run_config_overrides
from repro.models import build_model
from repro.train import (build_train_step, bus_layout_for, checkpoint,
                         init_state, make_gossip_schedule, resolve_features)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--agents", default="4",
                    help="agent count (int, agents='data'), or 'pod' for "
                         "shard-resident pod agents (DESIGN §7): one agent "
                         "per pod of --shards FSDP devices, --pods agents "
                         "total, gossip over row-sharded buses")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--per-agent-batch", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1,
                    help="pod count for torus/hier topologies; with "
                         "--agents pod, the number of pod-agents")
    ap.add_argument("--shards", type=int, default=0,
                    help="--agents pod: FSDP devices per pod-agent "
                         "(0 = device_count // pods)")
    ap.add_argument("--fused-kernel", action="store_true",
                    help="fused Pallas EDM update + gossip combine")
    # every RunConfig-backed lever (--algorithm, --topology, --gossip-*,
    # --packed-bus, --overlap, --wire, --gossip-groups, --alpha, --beta)
    # comes off the shared table — see repro.launch.flags.RUN_FLAGS
    add_run_flags(ap)
    ap.add_argument("--phi", type=float, default=0.2,
                    help="Dirichlet heterogeneity of the token streams")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--churn", default="",
                    help="liveness fault-injection plan (DESIGN §8): a JSON "
                         "file path or inline JSON DropPlan "
                         '({"n_agents": N, "epochs": [{"start": 0, '
                         '"down": [..]}, ..]}); wraps the gossip schedule '
                         "in an ElasticSchedule whose degraded rounds are "
                         "re-checked against Assumption 1 per epoch")
    ap.add_argument("--resume", default="",
                    help="checkpoint to resume from; the saved agent count "
                         "may differ from --agents (elastic join/leave): "
                         "surviving agents restore bit-exactly, re-admitted "
                         "agents join at the consensus mean with ψ := x")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    pod_agents = args.agents == "pod"
    if pod_agents:
        assert args.gossip_engine == "ppermute", \
            "--agents pod rides the shard-resident ppermute path " \
            "(set --gossip-engine ppermute)"
        n_agents = args.pods
        shards = args.shards or max(jax.device_count() // args.pods, 1)
    else:
        n_agents = int(args.agents)
        shards = 1
    run = RunConfig(global_batch=n_agents * args.per_agent_batch,
                    seq_len=args.seq,
                    agents="pod" if pod_agents else "data",
                    remat=False, **run_config_overrides(args))
    feats = resolve_features(run)
    sched = make_gossip_schedule(run, n_agents,
                                 pods=1 if pod_agents else args.pods,
                                 churn=args.churn or None)
    mesh = agent_axes = shard_axes = None
    if args.gossip_engine == "ppermute":
        from repro.launch.mesh import gossip_agent_axes, make_gossip_mesh
        if pod_agents:
            mesh = make_gossip_mesh(n_agents, pods=n_agents, shards=shards)
            agent_axes = gossip_agent_axes(mesh, sharded=True)
            shard_axes = "data"
        else:
            mesh = make_gossip_mesh(n_agents, pods=args.pods,
                                    agents_per_device=args.agents_per_device)
            agent_axes = gossip_agent_axes(mesh)
    stats = sched.product_spectral_stats()
    # --topology only feeds the static schedule; don't print it otherwise
    topo_str = (f"topo={args.topology} " if args.gossip_schedule == "static"
                else "")
    shard_str = f"x{shards}shards" if pod_agents else ""
    print(f"arch={cfg.name} ({cfg.n_params()/1e6:.1f}M params) "
          f"agents={n_agents}{shard_str} {topo_str}"
          f"schedule={sched.name} period={sched.period} "
          f"λ_prod={stats['lambda']:.4f} "
          f"alg={args.algorithm} engine={args.gossip_engine}"
          f"{' +fused' if args.fused_kernel else ''}"
          f"{' +bus' if feats.packed_bus else ''}"
          f"{' +overlap' if feats.overlap else ''}"
          f"{' wire=' + feats.wire if feats.wire != 'f32' else ''}"
          f"{' groups=' + ','.join(g.name for g in feats.groups) if feats.groups else ''}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       n_agents=n_agents, phi=args.phi)

    def sample(key):
        b = data.sample(key, args.per_agent_batch)
        if cfg.family in ("vlm", "encdec"):
            import jax.numpy as jnp
            b["frontend"] = jax.random.normal(
                jax.random.fold_in(key, 1),
                (n_agents, args.per_agent_batch, cfg.n_frontend_tokens,
                 cfg.d_model), dtype=jnp.dtype(cfg.dtype))
        return b

    layout = (bus_layout_for(model, n_agents, shards=shards,
                             groups=feats.groups)
              if feats.packed_bus else None)
    state = init_state(model, run, n_agents, jax.random.PRNGKey(0),
                       shards=shards)
    if args.resume:
        # elastic join/leave: the checkpoint's agent count may differ from
        # this run's — survivors restore bit-exactly, joiners take the
        # consensus mean with ψ := x (DESIGN §8)
        state = checkpoint.load_state_resized(args.resume, state,
                                              layout=layout)
        print(f"resumed <- {args.resume} @ step {int(state['step'])}")
    if pod_agents:
        # place the bus state shard-resident up front: agent axis on 'pod',
        # rows FSDP-sharded over 'data' (state_specs, DESIGN §7)
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.train import state_specs
        shardings = jax.tree.map(
            lambda sp: NamedSharding(mesh, sp),
            state_specs(model, run, multi_pod=True),
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        state = jax.tree.map(jax.device_put, state, shardings)
    # bus-resident state: donate so XLA aliases the superbuffers in place
    # (params/m/psi update without a second HBM copy, DESIGN §5)
    donate = (0,) if feats.packed_bus else ()
    step = jax.jit(build_train_step(model, run, sched,
                                    use_fused_kernel=args.fused_kernel,
                                    mesh=mesh, agent_axes=agent_axes,
                                    shard_axes=shard_axes,
                                    pods=1 if pod_agents else args.pods),
                   donate_argnums=donate)
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for t in range(args.steps):
        key, kd = jax.random.split(key)
        state, m = step(state, sample(kd))
        if t % 5 == 0 or t == args.steps - 1:
            print(f"step {t:4d} loss={float(m['loss']):.4f} "
                  f"consensus={float(m['consensus']):.2e} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        # full resumable state (params + opt + step + pipeline), stored as
        # logical trees — layout-, sharding- and overlap-mode-independent
        # on disk
        checkpoint.save_state(args.ckpt, state, layout=layout)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
