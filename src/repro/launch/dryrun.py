import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and extract memory / cost / collective analysis.

The two lines above MUST stay the very first statements: jax locks the device
count on first init, and the dry-run needs 512 placeholder host devices to
build the 2×16×16 production mesh.  Smoke tests / benches import jax normally
and see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--force]
Outputs one JSON per combo under experiments/dryrun/.
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.configs.base import ModelConfig, RunConfig
from repro.launch.hlo_analysis import collective_bytes, count_collectives, roofline
from repro.launch.mesh import HW, make_production_mesh
from repro.models.api import batch_specs, build_model, input_specs
from repro.serve.engine import (build_serve_step, serve_cache_specs,
                                serve_param_specs)
from repro.train.trainer import (batch_spec_tree, build_train_step, init_state,
                                 make_topology, state_specs)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def sanitize_specs(mesh, spec_tree, sds_tree):
    """Drop sharding on dimensions the mesh cannot divide evenly (e.g. a
    batch of 1 on a 16-way data axis, or 8 kv heads on a 16-way model axis).
    jit in_shardings require exact divisibility."""
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))

    def size(entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, tuple):
            n = 1
            for e in entry:
                n *= ax[e]
            return n
        return ax[entry]

    def fix(spec, sds):
        entries = list(spec)
        shape = sds.shape
        # PartitionSpec may be shorter than rank
        for i, e in enumerate(entries):
            if i >= len(shape) or (e is not None and shape[i] % size(e) != 0):
                entries[i] = None
        return P(*entries)

    return jax.tree.map(fix, spec_tree, sds_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _sharding_tree(mesh, spec_tree, sds_tree=None):
    if sds_tree is not None:
        spec_tree = sanitize_specs(mesh, spec_tree, sds_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals",
             "utilization operand 0 {}", "optimal_seconds")}


def _memory_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def _model_flops_per_device(cfg: ModelConfig, run: RunConfig,
                            n_devices: int) -> float:
    n_active = cfg.n_active_params()
    if run.mode == "train":
        tokens = run.global_batch * run.seq_len
        return 6.0 * n_active * tokens / n_devices
    if run.mode == "prefill":
        tokens = run.global_batch * run.seq_len
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per request
    return 2.0 * n_active * run.global_batch / n_devices


def _needs_fsdp(cfg: ModelConfig, tp: int = 16) -> bool:
    """Weights-per-chip beyond ~10 GB under pure 16-way TP → add ZeRO-style
    2-D weight sharding for the serving path."""
    return cfg.n_params() * 2 / tp > 10e9


def _lower(cfg: ModelConfig, run: RunConfig, mesh, multi_pod: bool,
           fsdp: bool, unroll: bool = False):
    """Build and lower the right step function for (cfg, run) on mesh."""
    from repro.models.moe import set_moe_mesh
    from repro.models import attention as _attn
    from repro.models import transformer as _tf
    _tf.set_seq_parallel_mesh(mesh if run.seq_parallel else None)
    if run.mode == "train" and run.agents == "pod" and cfg.family != "encdec":
        # per-layer FSDP re-constraint inside the scan body (ZeRO-3 gather)
        from repro.configs.base import block_period as _bp, layer_kinds as _lk
        from repro.models.transformer import _layer_specs
        period = _bp(cfg)
        specs = []
        for kind in _lk(cfg)[:period]:
            sp = _layer_specs(cfg, kind)

            def add_fsdp(spec):
                entries = list(spec)
                for i, e in enumerate(entries):
                    if e is None:
                        entries[i] = "data"
                        break
                return P(*entries)

            specs.append(jax.tree.map(add_fsdp, sp,
                                      is_leaf=lambda v: isinstance(v, P)))
        _tf.set_fsdp_constraint(mesh, tuple(specs))
    else:
        _tf.set_fsdp_constraint(None, None)
    if run.moe_impl == "shard_map":
        set_moe_mesh(mesh, impl="shard_map")
    else:
        set_moe_mesh(mesh if run.moe_sharding else None)
    _attn.set_bf16_path(run.attn_bf16_path)
    model = build_model(cfg, decode_window=run.decode_window, unroll=unroll)

    if run.mode == "train":
        if run.agents == "pod":
            A = 2 if multi_pod else 1
        else:
            A = 32 if multi_pod else 16
        topo = make_topology(run, A, pods=2 if multi_pod else 1)
        step = build_train_step(model, run, topo)
        state_sds = jax.eval_shape(
            lambda: init_state(model, run, A, jax.random.PRNGKey(0)))
        batch_sds = batch_specs(cfg, run, agent_axis=A)
        st_sh = _sharding_tree(mesh, state_specs(model, run, multi_pod),
                               state_sds)
        b_sh = _sharding_tree(mesh, batch_spec_tree(model, run, multi_pod),
                              batch_sds)
        lowered = jax.jit(step, in_shardings=(st_sh, b_sh)).lower(
            state_sds, batch_sds)
        return lowered, {"n_agents": A, "lambda": topo.lam()}

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = _sharding_tree(
        mesh, serve_param_specs(model, fsdp=fsdp, multi_pod=multi_pod),
        params_sds)
    dp = ("pod", "data") if multi_pod else "data"

    if run.mode == "prefill":
        batch_sds = batch_specs(cfg, run)
        b_spec = {"tokens": P(dp, None)}
        if cfg.family in ("vlm", "encdec"):
            b_spec["frontend"] = P(dp, None, None)
        b_sh = _sharding_tree(mesh, b_spec, batch_sds)

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        return jax.jit(prefill_fn, in_shardings=(p_sh, b_sh)).lower(
            params_sds, batch_sds), {}

    # decode
    ins = input_specs(cfg, run)
    c_sh = _sharding_tree(mesh, serve_cache_specs(model, multi_pod),
                          ins["caches"])
    t_sh = _sharding_tree(mesh, {"t": P(dp, None)}, {"t": ins["token"]})["t"]
    pos_sh = NamedSharding(mesh, P())
    serve_step = build_serve_step(model)
    return jax.jit(serve_step, in_shardings=(p_sh, c_sh, t_sh, pos_sh)).lower(
        params_sds, ins["caches"], ins["token"], ins["pos"]), {}


def _analyze(compiled):
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return cost, coll, hlo


def lower_combo(arch: str, shape: str, multi_pod: bool,
                run_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    from repro.configs.base import block_period
    cfg = get_config(arch)
    run = INPUT_SHAPES[shape]
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size
    fsdp = _needs_fsdp(cfg)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": run.mode, "algorithm": run.algorithm,
        "topology": run.topology, "agents": run.agents,
        "gossip_dtype": run.gossip_dtype, "fsdp_serving": fsdp,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }

    # ---- 1. full-size lower+compile: the lowering proof + memory analysis --
    t0 = time.time()
    lowered, extra = _lower(cfg, run, mesh, multi_pod, fsdp)
    rec.update(extra)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    rec["memory"] = _memory_dict(compiled)
    cost_scan, coll_scan, _ = _analyze(compiled)
    rec["cost_scan_body_once"] = cost_scan
    rec["collective_counts"] = count_collectives(compiled.as_text())

    # ---- 2. two-point layer extrapolation for honest cost terms -----------
    # XLA cost_analysis counts a lax.scan body ONCE; every roofline term is
    # affine in the number of layer blocks, so lower unrolled 1- and 2-block
    # variants and evaluate the fit at the full depth.
    period = block_period(cfg)
    nb_full = cfg.n_layers // period

    def small_cfg(nb):
        kw = {"n_layers": period * nb}
        if cfg.family == "encdec":
            kw["n_enc_layers"] = nb
        return dataclasses.replace(cfg, **kw)

    cost, coll = {}, {}
    if nb_full <= 3:
        # shallow stacks: lower the exact depth unrolled (no fit needed)
        c1, k1, _ = _analyze(_lower(small_cfg(nb_full), run, mesh, multi_pod,
                                    fsdp, unroll=True)[0].compile())
        cost, coll = c1, k1
    else:
        # fit at depths 2 and 3 (depth 1 sits in a different GSPMD regime for
        # FSDP decode programs and breaks affinity); clamp slope/intercept ≥0.
        c2, k2, _ = _analyze(_lower(small_cfg(2), run, mesh, multi_pod, fsdp,
                                    unroll=True)[0].compile())
        c3, k3, _ = _analyze(_lower(small_cfg(3), run, mesh, multi_pod, fsdp,
                                    unroll=True)[0].compile())

        def fit(d2, d3):
            out = {}
            for key in set(d2) | set(d3):
                v2, v3 = d2.get(key, 0.0), d3.get(key, 0.0)
                slope = max(0.0, v3 - v2)
                intercept = max(0.0, v2 - 2 * slope)
                out[key] = intercept + slope * nb_full
            return out

        cost, coll = fit(c2, c3), fit(k2, k3)

    rec["cost"] = cost
    rec["collective_bytes"] = coll
    rec["roofline"] = roofline(cost, coll, HW,
                               _model_flops_per_device(cfg, run, n_devices))
    rec["ok"] = True
    return rec


def _out_path(arch, shape, multi_pod, tag):
    mesh = "multi" if multi_pod else "single"
    suffix = f"_{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def run_one(arch, shape, multi_pod, force=False, tag="", **overrides):
    path = _out_path(arch, shape, multi_pod, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(OUT_DIR, exist_ok=True)
    try:
        rec = lower_combo(arch, shape, multi_pod, overrides or None)
    except Exception as e:  # a failure here is a bug in the system
        rec = {"arch": arch, "shape": shape,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for perf-variant runs")
    ap.add_argument("--algorithm", default=None)
    ap.add_argument("--topology", default=None)
    ap.add_argument("--agents", default=None)
    ap.add_argument("--gossip-dtype", default=None)
    ap.add_argument("--moe-sharding", action="store_true")
    ap.add_argument("--moe-impl", default=None, choices=["gspmd", "shard_map"])
    ap.add_argument("--remat-policy", default=None, choices=["full", "dots"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--attn-bf16-path", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for k in ("algorithm", "topology", "agents"):
        if getattr(args, k):
            overrides[k] = getattr(args, k)
    if args.gossip_dtype:
        overrides["gossip_dtype"] = args.gossip_dtype
    if args.moe_sharding:
        overrides["moe_sharding"] = True
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.attn_bf16_path:
        overrides["attn_bf16_path"] = True

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, force=args.force, tag=args.tag,
                              **overrides)
                status = "OK " if rec.get("ok") else "FAIL"
                r = rec.get("roofline", {})
                print(f"[{status}] {arch:24s} {shape:12s} "
                      f"{'multi ' if mp else 'single'} "
                      f"compile={rec.get('compile_s', '-'):>7}s "
                      f"bottleneck={r.get('bottleneck', '-'):<10} "
                      f"t=({r.get('t_compute_s', 0):.3e},"
                      f"{r.get('t_memory_s', 0):.3e},"
                      f"{r.get('t_collective_s', 0):.3e})s"
                      + ("" if rec.get("ok") else f"  {rec.get('error')}"),
                      flush=True)
                if rec.get("ok"):
                    mem = rec.get("memory", {})
                    print("      memory_analysis: " + ", ".join(
                        f"{k.split('_size')[0]}={v/1e9:.2f}GB"
                        for k, v in mem.items() if v) or "(n/a)")
                    print("      cost_analysis:   " + ", ".join(
                        f"{k}={v:.4g}" for k, v in rec.get("cost", {}).items())
                        + f" | collective_bytes={rec['collective_bytes'].get('total', 0):.4g}")
                n_fail += 0 if rec.get("ok") else 1
    print(f"\ndone; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
