"""Production mesh construction.

Functions, not module-level constants, so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 2 pods = 512 chips.
Mesh axes:
  pod   — crosses DCI (slow inter-pod links); EDM's gossip edge in "pod" mode
  data  — data parallel / decentralized agents; ICI
  model — tensor/expert parallel inside one agent; ICI
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_sim_mesh", "make_gossip_mesh",
           "gossip_agent_axes", "HW"]


# TPU v5e hardware constants used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sim_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_gossip_mesh(n_agents: int, pods: int = 1,
                     agents_per_device: int = 1, shards: int = 1):
    """Mesh whose device grid carries the agent grid — a block of
    ``agents_per_device`` agents per device, as the ppermute engine requires
    (DESIGN §3–4).

    Builds over the first ``n_agents // agents_per_device`` devices so it
    also works on a host-platform mesh forced larger than needed
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  One agent per
    device (the default) yields ``(pods, n_agents // pods)`` with axes
    ``('pod', 'data')`` for hierarchical topologies, else ``(n_agents,)``
    with ``('data',)``.  Blocked mode (``agents_per_device > 1`` — how the
    n=32 simulations run on 8-device hosts) always builds the single flat
    ``('data',)`` axis the blocked engine needs; hierarchical terms
    decompose inside the engine, not the mesh.

    Shard-resident mode (``shards > 1``, DESIGN §7): each agent spans a
    whole pod of ``shards`` FSDP devices — an ``(n_agents, shards)`` grid
    with axes ``('pod', 'data')`` where **'pod' is the agent axis and
    'data' the row-shard axis** (unlike the ``pods > 1`` grid above, where
    both axes carry agents).  Use agent_axes='pod', shard_axes='data'.
    """
    from jax.sharding import Mesh

    B = agents_per_device
    assert B >= 1 and n_agents % B == 0, (n_agents, B)
    assert n_agents % max(pods, 1) == 0, (n_agents, pods)
    assert shards >= 1, shards
    devices = jax.devices()
    if shards > 1:
        assert B == 1, "shard-resident gossip needs one agent per slice"
        assert pods in (1, n_agents), \
            "shards>1 makes every agent a pod — pods must equal n_agents"
        n_dev = n_agents * shards
        assert len(devices) >= n_dev, \
            f"need {n_dev} devices for {n_agents} pod-agents × {shards} " \
            f"shards, have {len(devices)}"
        grid = np.array(devices[:n_dev]).reshape(n_agents, shards)
        return Mesh(grid, ("pod", "data"))
    n_dev = n_agents // B
    assert len(devices) >= n_dev, \
        f"need {n_dev} devices for {B}-agent-per-device gossip, " \
        f"have {len(devices)}"
    if B > 1:
        return Mesh(np.array(devices[:n_dev]), ("data",))
    if pods > 1:
        grid = np.array(devices[:n_dev]).reshape(pods, n_dev // pods)
        return Mesh(grid, ("pod", "data"))
    return Mesh(np.array(devices[:n_dev]), ("data",))


def gossip_agent_axes(mesh, sharded: bool = False):
    """The agent_axes tuple/name the gossip engines consume on ``mesh``.

    ``sharded=True`` reads the mesh as a shard-resident pods × shards grid
    (DESIGN §7): only 'pod' carries agents — 'data' is the FSDP row-shard
    axis (pass it as ``shard_axes``)."""
    if sharded:
        assert "pod" in mesh.axis_names and "data" in mesh.axis_names, \
            mesh.axis_names
        return "pod"
    names = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    assert names, mesh.axis_names
    return names if len(names) > 1 else names[0]
