"""Production mesh construction.

Functions, not module-level constants, so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Target hardware: TPU v5e pods — 256 chips/pod (16×16), 2 pods = 512 chips.
Mesh axes:
  pod   — crosses DCI (slow inter-pod links); EDM's gossip edge in "pod" mode
  data  — data parallel / decentralized agents; ICI
  model — tensor/expert parallel inside one agent; ICI
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_sim_mesh", "HW"]


# TPU v5e hardware constants used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sim_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
