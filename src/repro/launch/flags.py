"""Table-driven CLI ↔ :class:`~repro.configs.base.RunConfig` mapping.

One table (:data:`RUN_FLAGS`) declares every launcher flag that feeds a
``RunConfig`` field; :func:`add_run_flags` registers them on an argparse
parser and :func:`run_config_overrides` reads them back as constructor
kwargs.  Launchers (train, serve, benches) share THIS table, so a flag
rename or a new run lever cannot drift between entry points — there is
exactly one flag per field, and deprecated aliases are declared in
:data:`DEPRECATED_ALIASES` (they parse into the canonical dest and emit a
``DeprecationWarning``).
"""
from __future__ import annotations

import argparse
import warnings
from typing import Any, Dict, Tuple

__all__ = ["RUN_FLAGS", "DEPRECATED_ALIASES", "add_run_flags",
           "run_config_overrides"]


# (flag, RunConfig field, add_argument kwargs) — the single source of truth
# for the flag → RunConfig mapping.  Flags not listed here (arch, steps,
# agent geometry, checkpoints) are launcher-local and never reach RunConfig
# directly.
RUN_FLAGS: Tuple[Tuple[str, str, Dict[str, Any]], ...] = (
    ("--algorithm", "algorithm", dict(
        default="edm",
        help="decentralized algorithm (e.g. edm, edm_ef, dsgd, dmsgd)")),
    ("--topology", "topology", dict(default="ring")),
    ("--gossip-engine", "gossip_engine", dict(
        default="shifts", choices=["dense", "shifts", "ppermute"],
        help="mixing engine; ppermute needs one device per agent block "
             "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
             "on CPU)")),
    ("--gossip-schedule", "gossip_schedule", dict(
        default="static", choices=["static", "round_robin", "alt_hier"],
        help="time-varying gossip schedule (DESIGN §4): round_robin = one "
             "permute/step one-peer exp rounds")),
    ("--gossip-period", "gossip_period", dict(
        type=int, default=0,
        help="alt_hier: intra-pod rounds per inter-pod round")),
    ("--gossip-seed", "gossip_seed", dict(
        type=int, default=0,
        help="round_robin: shuffle the offset order (0 = off)")),
    ("--agents-per-device", "agents_per_device", dict(
        type=int, default=1,
        help="blocked ppermute: agents per mesh device, so A > device "
             "count runs without the shifts fallback")),
    ("--packed-bus", "packed_bus", dict(
        default=None, action=argparse.BooleanOptionalAction,
        help="packed parameter bus (DESIGN §5): params + EDM state in one "
             "(A, rows, 128) superbuffer — one edm_update launch and one "
             "ppermute per gossip term per step.  Default: on for "
             "edm + ppermute")),
    ("--overlap", "overlap", dict(
        default="off", choices=["off", "delayed"],
        help="overlapped gossip pipeline (DESIGN §6): 'delayed' issues the "
             "double-buffered payload's permutes before the backward pass "
             "and combines after it (one-step-stale mixing; needs the "
             "packed bus), 'off' keeps gossip synchronous")),
    ("--wire", "wire", dict(
        default="f32", choices=["f32", "bf16", "int8"],
        help="gossip wire format (DESIGN §9): 'bf16'/'int8' quantize the "
             "bus permute payloads through the error-feedback codec (int8 "
             "carries per-block f32 scales; a bus-shaped residual rides in "
             "the opt state), cutting wire bytes 2x / ~4x at the f32 "
             "divergence floor.  Needs the packed bus; composes with "
             "--overlap delayed and --agents pod")),
    ("--gossip-groups", "gossip_groups", dict(
        default="",
        help="gossip policy groups (DESIGN §12): '' = one default group "
             "(bit-identical to the ungrouped bus); presets 'moe[:k]' / "
             "'ssm[:k]' put expert / conv+SSM-state leaves in their own "
             "group (k = group gossip_every, 0 = opt out of gossip); a "
             "JSON list ('[{\"name\": ..., \"match\": [...], "
             "\"gossip_every\": ..., \"wire\": ...}]') or '@file.json' "
             "gives explicit specs.  Needs the packed bus")),
    ("--gossip-every", "gossip_every", dict(
        type=int, default=1,
        help="gossip every k steps (local-EDM, §Perf); with "
             "--gossip-groups keep 1 and set per-group cadences instead")),
    ("--alpha", "alpha", dict(type=float, default=0.2)),
    ("--beta", "beta", dict(type=float, default=0.9)),
)

# deprecated alias → canonical flag; parses into the canonical dest with a
# DeprecationWarning, so old invocations keep working but cannot diverge.
DEPRECATED_ALIASES: Dict[str, str] = {
    "--optimizer": "--algorithm",
}


class _DeprecatedAlias(argparse.Action):
    def __call__(self, parser, namespace, values, option_string=None):
        warnings.warn(
            f"{option_string} is deprecated; use {self.metavar}",
            DeprecationWarning, stacklevel=2)
        print(f"warning: {option_string} is deprecated; "
              f"use {self.metavar}")
        setattr(namespace, self.dest, values)


def _dest(flag: str) -> str:
    return flag.lstrip("-").replace("-", "_")


def add_run_flags(ap: argparse.ArgumentParser) -> None:
    """Register every RunConfig-backed flag (plus deprecated aliases)."""
    canonical_dest = {}
    for flag, field, kwargs in RUN_FLAGS:
        ap.add_argument(flag, **kwargs)
        canonical_dest[flag] = _dest(flag)
    for alias, target in DEPRECATED_ALIASES.items():
        ap.add_argument(alias, dest=canonical_dest[target],
                        action=_DeprecatedAlias, metavar=target,
                        default=argparse.SUPPRESS,
                        help=f"deprecated alias for {target}")


def run_config_overrides(args: argparse.Namespace) -> Dict[str, Any]:
    """Parsed args → RunConfig constructor kwargs, straight off the table.
    ``--gossip-groups @file.json`` is dereferenced here."""
    out = {}
    for flag, field, _ in RUN_FLAGS:
        val = getattr(args, _dest(flag))
        if field == "gossip_groups" and isinstance(val, str) \
                and val.startswith("@"):
            with open(val[1:]) as f:
                val = f.read()
        out[field] = val
    return out
