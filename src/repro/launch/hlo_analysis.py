"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``compiled.cost_analysis()`` gives per-device FLOPs and HBM bytes, but NOT
collective bytes — we parse the optimized HLO text and sum the data moved by
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, normalized to *bytes crossing links per device*:

    all-gather       : out_bytes · (g-1)/g        (received shards)
    reduce-scatter   : in_bytes  · (g-1)/g  ≈ out_bytes · (g-1)
    all-reduce       : 2 · bytes · (g-1)/g        (RS + AG ring)
    all-to-all       : bytes · (g-1)/g
    collective-permute: bytes                     (one neighbor hop)
"""
from __future__ import annotations

import re
from typing import Dict, List

__all__ = ["collective_bytes", "roofline", "count_collectives"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape string like
    '(f32[16,8]{1,0}, bf16[4]{0})' or 'bf16[128,512]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [n_groups, group_size]
        return max(1, int(m.group(2)))
    return 2


def _iter_ops(hlo_text: str):
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},]+)\s+([\w\-]+)",
                     ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        # strip -start/-done variants (async collectives)
        base = op
        for suf in ("-start", "-done"):
            if base.endswith(suf):
                base = base[: -len(suf)]
        yield base, shape_str, ls


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    seen_done = set()
    for op, _, line in _iter_ops(hlo_text):
        if op in _COLLECTIVES and not line.split("=")[1].strip().startswith("("):
            pass
        if op in _COLLECTIVES:
            if "-done" in line.split("=", 1)[1][:60]:
                continue
            counts[op] = counts.get(op, 0) + 1
    return counts


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved per collective type (see module docstring)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for op, shape_str, line in _iter_ops(hlo_text):
        if op not in _COLLECTIVES:
            continue
        # async pairs: count the -start (has the real shape), skip -done
        if re.match(r"%?[\w.\-]+\s*=\s*[\w\[\]{},()]+\s+[\w\-]+-done", line):
            continue
        if "-done" in line and f"{op}-done" in line:
            continue
        b = _shape_bytes(shape_str)
        g = _group_size(line)
        eff = (g - 1) / g
        if op == "all-gather":
            out[op] += b * eff
        elif op == "reduce-scatter":
            out[op] += b * (g - 1)
        elif op == "all-reduce":
            out[op] += 2 * b * eff
        elif op == "all-to-all":
            out[op] += b * eff
        else:  # collective-permute
            out[op] += b
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline(cost: Dict[str, float], coll: Dict[str, float], hw: Dict[str, float],
             model_flops_per_device: float) -> Dict[str, float]:
    """Three roofline terms in seconds (per device; the SPMD module is the
    per-device program)."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = bytes_hbm / hw["hbm_bw"]
    t_coll = coll["total"] / hw["ici_bw"]
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "hlo_flops": flops,
        "hlo_bytes": bytes_hbm,
        "collective_bytes": coll["total"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom[1],
        "model_flops": model_flops_per_device,
        "useful_flop_frac": (model_flops_per_device / flops) if flops else 0.0,
    }
