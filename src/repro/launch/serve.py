"""Serving launcher: batched prefill + greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16 [--window 16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window KV cache size (0 = full)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, decode_window=args.window)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.family in ("vlm", "encdec"):
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_frontend_tokens, cfg.d_model),
            dtype=jnp.dtype(cfg.dtype))

    t0 = time.time()
    out = greedy_generate(model, params, batch, n_steps=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"window={args.window or 'full'}")
    print(f"generated {args.new_tokens} tokens/request in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    for i in range(min(args.batch, 4)):
        print(f"  req{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
