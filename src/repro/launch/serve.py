"""Serving launcher: batched prefill + greedy decode, or the
continuous-batching engine (DESIGN §10).

  # dense reference path (seed behavior)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16 [--window 16]

  # continuous batching over the paged KV cache
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --continuous-batching --max-slots 8 --page-size 8 --requests 16 \
      [--rate 50] [--window 16] [--ckpt consensus.npz]

  # chunked prefill fused into the decode dispatch (DESIGN §11)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --continuous-batching --prefill-chunk 8 --max-step-tokens 16 \
      --prompt-dist exact --max-slots 8 --page-size 8 --requests 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window KV cache size (0 = full)")
    ap.add_argument("--ckpt", default=None,
                    help="consensus-exported params .npz "
                         "(train.checkpoint.export_consensus)")
    # continuous-batching engine (DESIGN §10)
    ap.add_argument("--continuous-batching", action="store_true",
                    help="serve a Poisson request trace through the paged "
                         "continuous-batching engine instead of one fixed "
                         "batch")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page rows (multiple of 8)")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="concurrent decode slots")
    ap.add_argument("--requests", type=int, default=16,
                    help="requests in the Poisson trace")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--attn-impl", choices=("ref", "pallas"), default="ref")
    # chunked prefill (DESIGN §11)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: fixed chunk width in tokens "
                         "(None = legacy per-request exact-length prefill)")
    ap.add_argument("--max-step-tokens", type=int, default=None,
                    help="per-dispatch token budget (chunk + live decodes); "
                         "None = uncapped")
    ap.add_argument("--prompt-dist", choices=("bucket", "exact"),
                    default="bucket",
                    help="prompt-length draw: 'bucket' keeps compiles "
                         "bounded for the legacy path, 'exact' is a length "
                         "continuum (chunked path serves it compile-free)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, decode_window=args.window)
    if args.ckpt:
        from repro.train import checkpoint
        like = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        params = jax.tree.map(jnp.asarray,
                              checkpoint.load_consensus(args.ckpt, like))
        print(f"loaded consensus params from {args.ckpt}")
    else:
        params = model.init(jax.random.PRNGKey(0))

    if args.continuous_batching:
        from repro.serve import (ContinuousBatchingEngine, PagedCacheConfig,
                                 poisson_load)
        max_prompt, max_new = 32, 32
        ctx = args.window or max_prompt + max_new
        pcfg = PagedCacheConfig(
            page_size=args.page_size,
            num_pages=1 + args.max_slots * (-(-ctx // args.page_size)),
            max_slots=args.max_slots, max_context=ctx, window=args.window)
        eng = ContinuousBatchingEngine(model, params, pcfg,
                                       attn_impl=args.attn_impl,
                                       prefill_chunk=args.prefill_chunk,
                                       max_step_tokens=args.max_step_tokens)
        reqs = poisson_load(args.requests, args.rate, vocab=cfg.vocab_size,
                            prompt_buckets=(max_prompt // 2, max_prompt),
                            new_token_buckets=(4, 8, 16, max_new),
                            prompt_dist=args.prompt_dist, seed=1)
        metrics = eng.run(reqs)
        pf = (f"chunked(C={args.prefill_chunk})"
              if args.prefill_chunk else "per-request")
        print(f"arch={cfg.name} engine=continuous slots={args.max_slots} "
              f"page={args.page_size} window={args.window or 'full'} "
              f"attn={args.attn_impl} prefill={pf} "
              f"compiles={metrics['compile_count']}")
        print("serve metrics: " + json.dumps(metrics))
        print(f"generated {metrics['tokens']} tokens over "
              f"{metrics['requests']} requests "
              f"({metrics['tokens_per_s']} tok/s)")
        return

    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.family in ("vlm", "encdec"):
        batch["frontend"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_frontend_tokens, cfg.d_model),
            dtype=jnp.dtype(cfg.dtype))

    t0 = time.time()
    out = greedy_generate(model, params, batch, n_steps=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"window={args.window or 'full'}")
    print(f"generated {args.new_tokens} tokens/request in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    for i in range(min(args.batch, 4)):
        print(f"  req{i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
