"""repro.serve — inference substrate: dense reference path (engine),
paged KV cache + continuous-batching scheduler (DESIGN §10)."""
from .engine import (  # noqa: F401
    build_prefill, build_serve_step, greedy_generate, grow_caches,
    scale_specs_multipod, serve_cache_specs, serve_param_specs,
)
from .paged_cache import (  # noqa: F401
    NULL_PAGE, PageAllocator, PagedCacheConfig, init_paged_pools,
    paged_pool_shapes, paged_pool_specs,
)
from .scheduler import (  # noqa: F401
    ContinuousBatchingEngine, Request, build_paged_serve_step, poisson_load,
    run_fixed_batch, summarize,
)
