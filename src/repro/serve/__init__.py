"""repro.serve — inference substrate (KV caches, decode loop)."""
from .engine import (  # noqa: F401
    build_prefill, build_serve_step, greedy_generate, scale_specs_multipod,
    serve_cache_specs, serve_param_specs,
)
