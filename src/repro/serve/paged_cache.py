"""Paged KV cache for the continuous-batching serving engine (DESIGN §10).

The seed decode path pads every request's KV cache to the max sequence
length — a 32-token request in a 32k-slot batch pays 1000× its footprint.
Here KV storage is a **page pool**: fixed-size pages of ``page_size``
token-rows per attention layer, a per-slot **page table** mapping each
slot's logical page index to a physical page, and a host-side free-list
allocator.  Heterogeneous sequence lengths then cost what they use
(rounded up to one page), and admission/eviction is O(pages) pointer
surgery — no cache reshapes, no recompilation.

Layout contract (mirrors the packed-bus alignment idioms of DESIGN §5,
via :func:`repro.kernels.ops.padded_size`):

* a page holds ``page_size`` token-rows of ``(K, hd)`` each; ``page_size``
  is a multiple of the 8-row sublane so a ``(page_size, hd)`` page slice
  is a whole number of 8×128 VPU tiles when ``hd % 128 == 0`` (the
  full-size configs; smoke shapes run the kernel in interpret mode);
* physical page 0 is the **null page**: the allocator never hands it out,
  free slots' page-table rows are all-zero, and idle slots' decode writes
  land there — so a write by a dead slot can never corrupt a live one,
  and the masked-tail property "never read an unallocated page" is
  testable by poisoning every unallocated page with NaN;
* ring mode (``window > 0``): a slot owns exactly ``window / page_size``
  pages and token position p lives at ring row ``p % window`` — the same
  ring layout the dense decode path and prefill's rolled cache use, so
  prefill caches scatter into pages without re-indexing.

The pools themselves are device arrays shaped like the model's stacked
cache tree — ``(n_blocks, num_pages, page_size, K, hd)`` per period
position — and flow through the jitted ``serve_step`` unchanged; only the
allocator below is host-side Python.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, block_period, layer_kinds

__all__ = ["PagedCacheConfig", "PageAllocator", "init_paged_pools",
           "paged_pool_shapes", "paged_pool_specs", "NULL_PAGE"]

NULL_PAGE = 0          # reserved physical page: write sink for idle slots
_SUBLANE = 8           # token-rows per page must tile the 8-row sublane


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of the paged cache.

    ``max_context`` is the per-slot context ceiling (prompt + generated);
    ring mode caps it at ``window``.  ``num_pages`` counts *physical*
    pages including the reserved null page.
    """

    page_size: int
    num_pages: int
    max_slots: int
    max_context: int
    window: int = 0                 # 0 = linear; else ring of `window` rows

    def __post_init__(self):
        assert self.page_size > 0 and self.page_size % _SUBLANE == 0, \
            f"page_size must be a positive multiple of {_SUBLANE} rows " \
            f"(8×128-tileable pages), got {self.page_size}"
        if self.window:
            assert self.window % self.page_size == 0, \
                "ring mode needs window % page_size == 0 so a slot owns " \
                f"whole pages, got window={self.window} " \
                f"page_size={self.page_size}"
        assert self.num_pages > 1 + self.pages_per_slot, \
            ("page pool too small for even one slot "
             f"(num_pages={self.num_pages}, need "
             f"{1 + self.pages_per_slot}+)")

    @property
    def slot_context(self) -> int:
        """Rows of KV a slot can hold: the ring size in window mode, the
        context ceiling otherwise."""
        return self.window if self.window else self.max_context

    @property
    def pages_per_slot(self) -> int:
        """Width of one page-table row (logical pages per slot)."""
        return -(-self.slot_context // self.page_size)


def paged_pool_shapes(cfg: ModelConfig, pcfg: PagedCacheConfig):
    """ShapeDtypeStructs of the paged pool tree: one entry per period
    position, mirroring :func:`~repro.models.transformer.init_lm_cache`'s
    stacked block structure — pools scan over ``n_blocks`` exactly like
    dense caches do.  Attention-mixer positions get k/v page pools; the
    continuous engine is attention-family-only (SSM state is O(1)/slot
    and needs slot state, not pages — gated in the scheduler)."""
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    n_blocks = cfg.n_layers // period
    dt = jnp.dtype(cfg.dtype)
    shapes = []
    for mixer, _ in kinds:
        assert mixer == "attn", \
            "paged pools cover attention mixers only (SSM/hybrid decode " \
            "keeps O(1) per-slot state — see DESIGN §10 scope note)"
        leaf = jax.ShapeDtypeStruct(
            (n_blocks, pcfg.num_pages, pcfg.page_size, cfg.n_kv_heads,
             cfg.hd), dt)
        shapes.append({"k": leaf, "v": leaf})
    return tuple(shapes)


def init_paged_pools(cfg: ModelConfig, pcfg: PagedCacheConfig):
    """Zero-filled page pools (device arrays)."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_pool_shapes(cfg, pcfg))


def paged_pool_specs(cfg: ModelConfig):
    """TP PartitionSpecs for the pools: kv heads over 'model', pages
    replicated-free of any collective — the paged kernel's page gather is
    slot-local, so the decode step is **ppermute-free** and composes with
    ``serve_param_specs`` (the head axis is the same 'model' axis the
    dense ``lm_cache_specs`` shard)."""
    from jax.sharding import PartitionSpec as P
    period = block_period(cfg)
    spec = {"k": P(None, None, None, "model", None),
            "v": P(None, None, None, "model", None)}
    return tuple(spec for _ in range(period))


class PageAllocator:
    """Host-side page-table bookkeeping: free-list page allocation and
    slot admit/release.  Pure numpy — the scheduler calls this between
    jitted decode steps and ships ``page_table``/``lengths`` to device
    once per step (two small int32 arrays, not the pools).

    Invariants (asserted):

    * physical page ``NULL_PAGE`` is never allocated;
    * a live slot's pages are disjoint from every other live slot's;
    * free slots' page-table rows are all-``NULL_PAGE`` and their length
      is 0 (their decode writes sink into the null page);
    * chunked-prefill slots (DESIGN §11): ``prefill_cursor`` counts prompt
      rows already written, ``lengths == prefill_cursor`` while
      ``prefilling`` and ``prefill_cursor <= prompt_len`` always — all
      pages are reserved at admission, so a mid-prefill slot can never
      OOM and its pages never move.
    """

    def __init__(self, pcfg: PagedCacheConfig):
        self.cfg = pcfg
        self.free_pages: List[int] = list(range(pcfg.num_pages - 1, 0, -1))
        self.free_slots: List[int] = list(range(pcfg.max_slots - 1, -1, -1))
        self.page_table = np.zeros((pcfg.max_slots, pcfg.pages_per_slot),
                                   np.int32)
        self.lengths = np.zeros((pcfg.max_slots,), np.int32)
        self.active = np.zeros((pcfg.max_slots,), bool)
        # chunked-prefill slot state (DESIGN §11)
        self.prompt_len = np.zeros((pcfg.max_slots,), np.int32)
        self.prefill_cursor = np.zeros((pcfg.max_slots,), np.int32)
        self.prefilling = np.zeros((pcfg.max_slots,), bool)

    # -- capacity queries ---------------------------------------------------

    def pages_needed(self, context_len: int) -> int:
        """Pages a slot with ``context_len`` total rows needs — the whole
        ring in window mode (the slot cycles through all of them)."""
        ctx = min(context_len, self.cfg.slot_context)
        if self.cfg.window:
            return self.cfg.pages_per_slot
        return -(-ctx // self.cfg.page_size)

    def can_admit(self, context_len: int) -> bool:
        return (bool(self.free_slots)
                and self.pages_needed(context_len) <= len(self.free_pages))

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def pages_in_use(self) -> int:
        return (self.cfg.num_pages - 1) - len(self.free_pages)

    # -- admit / advance / release -----------------------------------------

    def admit(self, context_len: int, prompt_len: int, *,
              chunked: bool = False) -> int:
        """Reserve a slot + pages for a request whose total context will
        reach ``context_len`` rows (prompt + worst-case generation, capped
        by the ring in window mode).  All pages are reserved up front —
        no mid-decode allocation, so an admitted request can never OOM
        (and a ``chunked`` admission can never OOM *mid-prefill*).
        Returns the slot id.

        ``chunked=True`` admits for chunked prefill (DESIGN §11): the slot
        starts with ZERO written rows (``lengths = 0``) and a prefill
        cursor that :meth:`advance_prefill` walks to ``prompt_len`` one
        chunk at a time; ``chunked=False`` is the per-request-prefill
        path, where all ``prompt_len`` rows are scattered on admission."""
        assert context_len >= prompt_len > 0, (context_len, prompt_len)
        assert self.cfg.window or context_len <= self.cfg.max_context, \
            (context_len, self.cfg.max_context)
        assert self.can_admit(context_len), \
            f"admit() without can_admit(): {len(self.free_slots)} slots, " \
            f"{len(self.free_pages)} pages free"
        slot = self.free_slots.pop()
        n = self.pages_needed(context_len)
        pages = [self.free_pages.pop() for _ in range(n)]
        row = np.full((self.cfg.pages_per_slot,), NULL_PAGE, np.int32)
        row[:n] = pages
        self.page_table[slot] = row
        self.prompt_len[slot] = prompt_len
        self.prefill_cursor[slot] = 0 if chunked else prompt_len
        self.prefilling[slot] = chunked
        self.lengths[slot] = 0 if chunked else prompt_len
        self.active[slot] = True
        return slot

    def advance(self, slot: int, n: int = 1) -> None:
        """Account ``n`` decoded rows on ``slot`` (the device write already
        happened inside ``serve_step``; this keeps the host mirror and the
        next step's write position in sync).  ``lengths`` tracks the TRUE
        absolute length even in ring mode — the ring write row is
        ``length % window`` and RoPE needs the absolute position; the
        number of *valid* KV rows is ``min(length, window)``."""
        assert self.active[slot], slot
        assert not self.prefilling[slot], \
            f"decode advance on mid-prefill slot {slot}"
        self.lengths[slot] = int(self.lengths[slot]) + n
        assert self.cfg.window or self.lengths[slot] <= self.cfg.max_context, \
            (slot, int(self.lengths[slot]), self.cfg.max_context)

    def advance_prefill(self, slot: int, n: int) -> None:
        """Account ``n`` prompt rows written by a prefill chunk
        (DESIGN §11).  Keeps ``lengths == prefill_cursor`` so the decode
        dispatch's write position and RoPE base stay consistent with the
        pages actually filled; the slot leaves ``prefilling`` exactly when
        the cursor reaches the TRUE prompt length."""
        assert self.active[slot] and self.prefilling[slot], slot
        assert n >= 1, n
        cur = int(self.prefill_cursor[slot]) + n
        assert cur <= self.prompt_len[slot], \
            (slot, cur, int(self.prompt_len[slot]))
        self.prefill_cursor[slot] = cur
        self.lengths[slot] = cur
        if cur == self.prompt_len[slot]:
            self.prefilling[slot] = False

    def release(self, slot: int) -> None:
        """Evict: return the slot's pages to the free list and zero its
        page-table row (writes from the now-idle slot sink to the null
        page)."""
        assert self.active[slot], f"release of inactive slot {slot}"
        for p in self.page_table[slot]:
            if p != NULL_PAGE:
                self.free_pages.append(int(p))
        self.page_table[slot] = NULL_PAGE
        self.lengths[slot] = 0
        self.prompt_len[slot] = 0
        self.prefill_cursor[slot] = 0
        self.prefilling[slot] = False
        self.active[slot] = False
        self.free_slots.append(slot)

    # -- device views -------------------------------------------------------

    def device_tables(self) -> Tuple[jax.Array, jax.Array]:
        """(page_table, lengths) as device arrays for this decode step."""
        return jnp.asarray(self.page_table), jnp.asarray(self.lengths)

    def decode_tables(self) -> Tuple[jax.Array, jax.Array]:
        """(page_table, lengths) for the DECODE half of a mixed dispatch
        (DESIGN §11): mid-prefill slots' page-table rows are masked to the
        null page, so their (junk) decode write sinks harmlessly instead
        of corrupting a page the next prefill chunk will read — in ring
        mode the decode write row ``length % window`` aliases a LIVE ring
        row once the ring is full, so the mask is load-bearing, not just
        hygiene."""
        pt = self.page_table.copy()
        pt[self.prefilling] = NULL_PAGE
        return jnp.asarray(pt), jnp.asarray(self.lengths)
