"""Serving substrate: prefill + batched greedy decode with KV / SSM caches.

Decentralized training is a train-time technique; serving uses a single
replica sharded TP (+ ZeRO-style 2-D weight sharding for models that exceed
one chip-row's HBM).  ``build_serve_step`` is what the decode dry-run shapes
(decode_32k, long_500k) lower.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models.api import Model, build_model
from repro.train.trainer import prepend_agent_axis

__all__ = [
    "build_serve_step", "build_prefill", "serve_param_specs",
    "serve_cache_specs", "scale_specs_multipod", "greedy_generate",
]


def build_serve_step(model: Model):
    """serve_step(params, caches, token, pos) -> (next_token, new_caches).

    One new token per request against a seq_len-deep cache (greedy head)."""

    def serve_step(params, caches, token, pos):
        logits, new_caches = model.decode_step(params, caches, token, pos)
        next_token = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_token.astype(jnp.int32)[:, None], new_caches

    return serve_step


def build_prefill(model: Model):
    def prefill(params, batch):
        return model.prefill(params, batch)
    return prefill


def serve_param_specs(model: Model, *, fsdp: bool, multi_pod: bool):
    """TP specs; with fsdp=True the first unsharded dim of each ≥2-D weight is
    additionally sharded over ('data',) (ZeRO-3-style, weights gathered on
    use) — required for jamba-398b-class models at 16 GB/chip."""
    base = model.param_specs()
    axis = ("pod", "data") if multi_pod else "data"

    def lift(s: P) -> P:
        if not fsdp:
            return s
        entries = list(s)
        if sum(e is not None for e in entries) >= len(entries):
            return s
        for i, e in enumerate(entries):
            if e is None:
                entries[i] = axis
                break
        return P(*entries)

    return jax.tree.map(lift, base, is_leaf=lambda s: isinstance(s, P))


def serve_cache_specs(model: Model, multi_pod: bool):
    specs = model.cache_specs()
    if multi_pod:
        specs = scale_specs_multipod(specs)
    return specs


def scale_specs_multipod(spec_tree):
    """Map every 'data' mesh-axis reference to ('pod','data')."""

    def f(s: P) -> P:
        return P(*(("pod", "data") if e == "data" else e for e in s))

    return jax.tree.map(f, spec_tree, is_leaf=lambda s: isinstance(s, P))


@functools.lru_cache(maxsize=64)
def _jitted_serve_step(model: Model):
    """One jitted serve_step per Model (frozen dataclass ⇒ hashable):
    repeated ``greedy_generate`` calls at one shape reuse the compile."""
    return jax.jit(build_serve_step(model))


def grow_caches(model: Model, caches, batch_size: int, target_len: int):
    """Layout-driven cache growth: pad every prefill-cache leaf out to the
    shape ``model.init_cache(batch_size, target_len)`` would allocate.

    The target tree is derived with ``jax.eval_shape`` — no allocation —
    and each leaf is grown along whichever single axis differs, so the
    sequence axis is located by the model's own cache layout instead of
    leaf-name matching.  Length-independent leaves (SSM state, conv tails,
    cross-attention KV, ring-window caches already at ``window``) come
    back shape-identical and pass through untouched — they can't be
    silently mis-grown."""
    target = jax.eval_shape(lambda: model.init_cache(batch_size, target_len))

    def grow(c, t):
        cur, want = tuple(c.shape), tuple(t.shape)
        if cur == want:
            return c
        assert len(cur) == len(want), (cur, want)
        diff = [i for i, (a, b) in enumerate(zip(cur, want)) if a != b]
        assert len(diff) == 1 and want[diff[0]] > cur[diff[0]], \
            f"cache leaf {cur} does not grow to {want} along one axis"
        ax = diff[0]
        pad = [(0, 0)] * len(cur)
        pad[ax] = (0, want[ax] - cur[ax])
        return jnp.pad(c, pad)

    return jax.tree.map(grow, caches, target)


def greedy_generate(model: Model, params, batch: Dict[str, Any],
                    n_steps: int) -> jax.Array:
    """End-to-end: prefill the prompt, then greedy-decode n_steps tokens.
    Returns (B, n_steps) generated ids.  This is the dense reference path
    the continuous-batching engine (serve/scheduler.py) is gated against.

    The per-token step is jitted once and the grown cache is preallocated
    once (:func:`grow_caches`) — no per-step Python dispatch of a freshly
    traced step, no O(n_steps) ``concatenate`` re-layouts."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    n_front = model.cfg.n_frontend_tokens if model.cfg.family == "vlm" else 0
    logits, caches = model.prefill(params, batch)

    # grow self-attention caches to hold prompt + generation (ring-window
    # caches are already terminal-size and pass through)
    L0 = S + n_front
    caches = grow_caches(model, caches, B,
                         model.decode_window or L0 + n_steps)
    step = _jitted_serve_step(model)
    tok = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(n_steps - 1):
        tok, caches = step(params, caches, tok, jnp.asarray(L0 + i, jnp.int32))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
