"""Continuous-batching decode scheduler over the paged KV cache (DESIGN §10).

The seed serving path is batch-synchronous: every request in a batch decodes
until the LAST one finishes (head-of-line blocking) and pays KV for the
longest context (padding).  Here the decode batch is a set of **slots**:
each jitted ``serve_step`` decodes every live slot in ONE dispatch, finished
requests release their pages immediately, and arrivals are admitted the
moment a slot + pages are free — so throughput tracks the *mean* request
length, not the max.

Division of labor:

* device — ``build_paged_serve_step``: embed → paged-attention block scan →
  greedy head, for the whole slot batch, jitted once (shapes are static:
  ``max_slots`` slots, fixed page-table width);
* host — :class:`ContinuousBatchingEngine`: page allocator bookkeeping,
  per-request prefill + page scatter on admit, EOS/max-token eviction, and
  the arrival loop.  Per step it ships two small int32 tables (page table,
  kv lengths) and syncs one (B, 1) token array — no cache movement.

Two prefill paths (DESIGN §11):

* **legacy per-request** (``prefill_chunk=None``): prefill runs per request
  at its EXACT prompt length — a compile per distinct length, bounded by a
  size-capped LRU of per-length jit instances (``prefill_cache_cap``) and
  by the load generator's bucketed prompt draws.  Right-padding prompts
  instead would corrupt the ring-cache layout (row = position mod window)
  and the last-position prefill logits.  Every live decode slot stalls
  while a prefill runs — the head-of-line cost the bench measures.
* **chunked** (``prefill_chunk=C``): prompts are split into fixed-size
  C-token chunks (last chunk padded, ``chunk_len`` masked) and ONE mixed
  jitted step advances every live decode slot AND at most one chunk per
  dispatch, under a per-step token budget (``max_step_tokens``).  All
  shapes are static, so the whole serving trace needs exactly TWO compiles
  (mixed + decode-only) independent of the prompt-length distribution —
  ``compile_count`` makes that assertable.

``poisson_load`` generates open-loop Poisson arrivals with heterogeneous
prompt/output lengths; ``run_fixed_batch`` is the seed-style baseline the
benchmark gates the engine against (same step math, batch-synchronous
scheduling), instrumented per token so p50/p99 latencies are comparable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from .paged_cache import PageAllocator, PagedCacheConfig, init_paged_pools

__all__ = ["Request", "poisson_load", "build_paged_serve_step",
           "ContinuousBatchingEngine", "run_fixed_batch", "summarize"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (S,) int32 prompt ids
    max_new: int                # generation budget incl. the prefill token
    arrival: float              # seconds after load start (open loop)
    eos_id: int = -1            # -1: disabled (random-weight smokes)


def poisson_load(n_requests: int, rate: float, *, vocab: int,
                 prompt_buckets=(16, 32), new_token_buckets=(8, 16, 32, 96),
                 prompt_dist: str = "bucket", seed: int = 0,
                 eos_id: int = -1) -> List[Request]:
    """Open-loop Poisson arrivals (exponential gaps at ``rate`` req/s) with
    prompt lengths and generation budgets drawn from small bucket sets —
    heterogeneous enough to expose head-of-line blocking.

    ``prompt_dist`` selects the prompt-length draw:

    * ``"bucket"`` (default): uniform over ``prompt_buckets``.  This is a
      **legacy-path accommodation**, not a realism choice: the per-request
      prefill engine pays one XLA compile per DISTINCT prompt length, so
      an un-bucketed draw turns a load test into a compile storm.  Keeping
      the bucketed draw as the default keeps older callers honest about
      what they can afford.
    * ``"exact"``: uniform integer over ``[min(prompt_buckets),
      max(prompt_buckets)]`` — a length continuum no compile cache can
      pre-warm.  This is what real traffic looks like, and the chunked
      engine serves it with a CONSTANT compile count (static chunk
      shapes); on the legacy path it measures the compile storm itself.
    """
    assert prompt_dist in ("bucket", "exact"), prompt_dist
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    lo, hi = min(prompt_buckets), max(prompt_buckets)
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        if prompt_dist == "bucket":
            S = int(rng.choice(prompt_buckets))
        else:
            S = int(rng.integers(lo, hi + 1))
        out.append(Request(
            rid=rid,
            tokens=rng.integers(0, vocab, (S,)).astype(np.int32),
            max_new=int(rng.choice(new_token_buckets)),
            arrival=t, eos_id=eos_id))
    return out


def build_paged_serve_step(model: Model, *, attn_impl: str = "ref",
                           page_size: Optional[int] = None,
                           mixed: bool = False) -> Callable:
    """jitted ``step(params, pools, token, positions, page_table, kv_len)``
    → ``(next_token (B, 1), new_pools)``: one dispatch decodes the whole
    slot batch through the paged cache (greedy head).

    ``mixed=True`` builds the chunked-prefill fused step (DESIGN §11):
    ``step(params, pools, token, positions, page_table, kv_len,
    chunk_tokens, pt_row, chunk_start, chunk_len)`` →
    ``(next_token (B, 1), chunk_next (C,), new_pools)`` — the decode batch
    plus ONE prompt chunk of one slot in a single weight scan.
    ``chunk_next[i]`` is the greedy token after chunk position i; the
    engine reads row ``chunk_len - 1`` when the chunk completes a prompt
    (rows past ``chunk_len`` are padding garbage).  ``chunk_start`` /
    ``chunk_len`` are traced 0-d int32 — NOT shapes — so every chunk of
    every prompt length reuses this one compile.

    ``attn_impl``: "ref" is the pure-jnp gather + ``sdpa_ref`` path — the
    bit-exactness anchor the divergence gate relies on; "pallas" reads the
    page pool directly through :func:`repro.kernels.ops.paged_attention`
    (decode) and :func:`repro.kernels.ops.paged_prefill_attention`
    (chunk) — page-table gather in the BlockSpec index map, no dense
    gather."""
    assert model.decode_step_paged is not None, \
        f"{model.cfg.family}: no paged decode path (attention families only)"
    window = model.decode_window
    if attn_impl == "ref":
        attn_fn = prefill_attn_fn = None
    else:
        assert attn_impl == "pallas" and page_size is not None
        from repro.kernels.ops import paged_attention, paged_prefill_attention

        def attn_fn(q, k_pool, v_pool, page_table, kv_len):
            return paged_attention(q, k_pool, v_pool, page_table, kv_len,
                                   page_size=page_size)

        def prefill_attn_fn(q, k_chunk, v_chunk, k_pool, v_pool, pt_row,
                            chunk_start, chunk_len):
            return paged_prefill_attention(
                q, k_chunk, v_chunk, k_pool, v_pool, pt_row, chunk_start,
                chunk_len, page_size=page_size, window=window)

    if not mixed:
        def step(params, pools, token, positions, page_table, kv_len):
            logits, pools = model.decode_step_paged(
                params, pools, token, positions, page_table, kv_len,
                attn_fn=attn_fn)
            nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
            return nxt.astype(jnp.int32)[:, None], pools

        return jax.jit(step)

    assert model.decode_step_mixed is not None, \
        f"{model.cfg.family}: no mixed serving step (attention families only)"

    def mixed_step(params, pools, token, positions, page_table, kv_len,
                   chunk_tokens, pt_row, chunk_start, chunk_len):
        d_logits, c_logits, pools = model.decode_step_mixed(
            params, pools, token, positions, page_table, kv_len,
            chunk_tokens, pt_row, chunk_start, chunk_len,
            attn_fn=attn_fn, prefill_attn_fn=prefill_attn_fn)
        nxt = jnp.argmax(d_logits[:, -1].astype(jnp.float32), axis=-1)
        cn = jnp.argmax(c_logits[0].astype(jnp.float32), axis=-1)
        return nxt.astype(jnp.int32)[:, None], cn.astype(jnp.int32), pools

    return jax.jit(mixed_step)


@dataclasses.dataclass
class _Live:
    req: Request
    slot: int
    emitted: List[int]
    t_last: float               # emission time of the latest token


@dataclasses.dataclass
class _Fill:
    """A slot mid-chunked-prefill: admitted (pages reserved), prompt being
    written one chunk per mixed dispatch, no token emitted yet."""
    req: Request
    slot: int


class ContinuousBatchingEngine:
    """Slot-based continuous batching: admit on free pages, decode every
    live slot per dispatch, evict on EOS/max-tokens.

    Greedy decoding on the ``attn_impl="ref"`` backend is **token-exact**
    vs the dense reference :func:`repro.serve.engine.greedy_generate`:
    identical q/k/v values flow through the same ``sdpa_ref`` ops, and
    page-padding columns contribute exactly 0.0 under softmax
    (``exp(-1e30 − m)`` underflows to 0.0, and adding 0.0 to a float sum
    is the identity).  Logits agree to float32 rounding — the padded
    attention width changes XLA's reduction splitting, so the last ulp
    can wiggle without ever moving the argmax — see ``tests/test_serve.py``.

    ``prefill_chunk=C`` switches prompt processing to chunked prefill
    (DESIGN §11): admission only reserves a slot + pages, then each
    dispatch runs the fused mixed step — every live decode slot plus at
    most one C-token chunk of the OLDEST mid-prefill slot (FIFO), capped
    by ``max_step_tokens`` (chunk tokens + decode tokens per dispatch).
    The same argument chain gives token-exactness: chunk rows flow
    through the identical rope/sdpa ops at identical absolute positions,
    and the padded tail of the last chunk is masked out of the attention
    and scattered to the null page.

    ``compile_count`` counts engine-level jitted callables as they are
    built: per-prompt-length prefill and per-page-count scatter instances
    on the legacy path (kept in an LRU bounded by ``prefill_cache_cap`` —
    an evicted length recompiles on return), plus one each for the
    decode-only / mixed steps on first use.  It survives ``reset()`` so a
    warm→reset→measure bench can assert the measured phase compiled
    nothing new.
    """

    def __init__(self, model: Model, params, pcfg: PagedCacheConfig, *,
                 attn_impl: str = "ref", prefill_chunk: Optional[int] = None,
                 max_step_tokens: Optional[int] = None,
                 prefill_cache_cap: int = 8):
        assert model.decode_window == pcfg.window, \
            (model.decode_window, pcfg.window)
        self.model, self.params, self.pcfg = model, params, pcfg
        self.alloc = PageAllocator(pcfg)
        self.pools = init_paged_pools(model.cfg, pcfg)
        self.prefill_chunk = prefill_chunk
        if prefill_chunk is not None:
            assert prefill_chunk >= 1, prefill_chunk
            # ring scatter writes chunk rows at position % window: a chunk
            # wider than the ring would collide with itself
            assert not pcfg.window or prefill_chunk <= pcfg.window, \
                (prefill_chunk, pcfg.window)
        assert max_step_tokens is None or max_step_tokens >= 1
        self.max_step_tokens = max_step_tokens
        assert prefill_cache_cap >= 1, prefill_cache_cap
        self.prefill_cache_cap = prefill_cache_cap
        self.compile_count = 0
        from collections import OrderedDict
        self._jit_cache: "OrderedDict[Any, Callable]" = OrderedDict()
        self._step = None           # decode-only step, built on first use
        self._mixed = None          # mixed step, built on first use
        self._attn_impl = attn_impl
        self.reset()

    def reset(self) -> None:
        """Fresh serving state (allocator, slots, metrics) with the jitted
        step/prefill/scatter callables AND ``compile_count`` retained —
        benchmarks warm up the compiles on a throwaway trace, reset, then
        measure.  Pools keep stale pages: every page is re-written
        (prefill scatter / chunk scatter / decode write) before ``kv_len``
        ever exposes it, so stale rows are unreachable by construction
        (the masked-tail contract)."""
        pcfg = self.pcfg
        self.alloc = PageAllocator(pcfg)
        if not hasattr(self, "pools"):
            self.pools = init_paged_pools(self.model.cfg, pcfg)
        self.tok = np.zeros((pcfg.max_slots, 1), np.int32)
        self.live: Dict[int, _Live] = {}          # slot -> decoding state
        self._filling: List[_Fill] = []           # FIFO of mid-prefill slots
        self.completed: Dict[int, np.ndarray] = {}  # rid -> generated ids
        self.latencies: List[float] = []          # per emitted token (s)
        self.ttfts: List[float] = []              # arrival -> first token (s)
        self.queue_waits: List[float] = []        # arrival -> admission (s)
        self.steps = 0
        self._t0 = time.perf_counter()            # run() resets; absolute

    # -- compile accounting -------------------------------------------------

    def _cached_jit(self, key, factory) -> Callable:
        """Size-capped LRU of jitted callables, keyed by what pins their
        compiled shape (prompt length, page count).  A miss builds a FRESH
        ``jax.jit`` instance — so evicting an entry really frees its
        executable, and re-encountering the length really recompiles —
        and bumps ``compile_count``."""
        cache = self._jit_cache
        if key in cache:
            cache.move_to_end(key)
            return cache[key]
        fn = factory()
        self.compile_count += 1
        cache[key] = fn
        while len(cache) > self.prefill_cache_cap:
            cache.popitem(last=False)
        return fn

    def _decode_step(self) -> Callable:
        if self._step is None:
            self._step = build_paged_serve_step(
                self.model, attn_impl=self._attn_impl,
                page_size=self.pcfg.page_size)
            self.compile_count += 1
        return self._step

    def _mixed_step(self) -> Callable:
        if self._mixed is None:
            self._mixed = build_paged_serve_step(
                self.model, attn_impl=self._attn_impl,
                page_size=self.pcfg.page_size, mixed=True)
            self.compile_count += 1
        return self._mixed

    # -- device helpers -----------------------------------------------------

    @staticmethod
    def _scatter_impl(pools, caches, pages):
        """Scatter one request's dense prefill cache into its pages.
        caches leaf: (n_blocks, 1, L, K, hd); pages: (n_used,) physical
        ids.  Logical row r lands at row ``r % page_size`` of page
        ``pages[r // page_size]`` — for ring caches L == window and the
        rolled prefill layout maps through unchanged."""

        def one(pool, c):
            n_blocks, _, L, K, hd = c.shape
            ps = pool.shape[2]
            n_used = pages.shape[0]
            rows = jnp.pad(c[:, 0], ((0, 0), (0, n_used * ps - L),
                                     (0, 0), (0, 0)))
            rows = rows.reshape(n_blocks, n_used, ps, K, hd)
            return pool.at[:, pages].set(rows)

        return jax.tree.map(one, pools, caches)

    # -- admission / eviction -----------------------------------------------

    def try_admit(self, req: Request) -> bool:
        """Admit if a slot and enough pages are free.

        Legacy path: per-request prefill + page scatter, emitting the
        request's first token (prefill argmax) before returning.  Chunked
        path: reservation only — the prompt is processed one chunk per
        mixed dispatch and the first token is emitted by the dispatch that
        completes the last chunk."""
        S = int(req.tokens.shape[0])
        # rows the slot will hold: prompt + every fed-back token (the
        # final emitted token is never fed, hence max_new − 1)
        ctx = S + req.max_new - 1
        if not self.alloc.can_admit(ctx):
            return False
        now = time.perf_counter()
        self.queue_waits.append(now - (self._t0 + req.arrival))
        if self.prefill_chunk is not None:
            slot = self.alloc.admit(ctx, S, chunked=True)
            self._filling.append(_Fill(req=req, slot=slot))
            return True
        slot = self.alloc.admit(ctx, S)
        prefill = self._cached_jit(("prefill", S),
                                   lambda: jax.jit(self.model.prefill))
        logits, caches = prefill(self.params,
                                 {"tokens": jnp.asarray(req.tokens)[None]})
        n_used = self.alloc.pages_needed(ctx)
        scatter = self._cached_jit(("scatter", n_used),
                                   lambda: jax.jit(self._scatter_impl))
        pages = jnp.asarray(self.alloc.page_table[slot, :n_used])
        self.pools = scatter(self.pools, caches, pages)
        tok0 = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
        now = time.perf_counter()
        st = _Live(req=req, slot=slot, emitted=[tok0], t_last=now)
        # TTFT of token #1 (queue wait + prefill), on the absolute clock
        ttft = now - (self._t0 + req.arrival)
        self.latencies.append(ttft)
        self.ttfts.append(ttft)
        if req.max_new == 1 or tok0 == req.eos_id:
            self._finish(st)
        else:
            self.tok[slot, 0] = tok0
            self.live[slot] = st
        return True

    def _finish(self, st: _Live) -> None:
        self.completed[st.req.rid] = np.asarray(st.emitted, np.int32)
        self.alloc.release(st.slot)
        self.tok[st.slot, 0] = 0
        self.live.pop(st.slot, None)

    # -- decode -------------------------------------------------------------

    def _decode_inputs(self):
        """(positions, page_table, kv_len) for the decode half of a
        dispatch.  Mid-prefill slots are masked OUT: kv_len 0 and a
        null page-table row — in ring mode their decode-side write row
        ``length % window`` aliases a LIVE ring row, so the mask is
        correctness, not hygiene (see ``PageAllocator.decode_tables``)."""
        lens = self.alloc.lengths
        decoding = self.alloc.active & ~self.alloc.prefilling
        kv = np.where(decoding, lens + 1, 0).astype(np.int32)
        if self.pcfg.window:
            kv = np.minimum(kv, self.pcfg.window).astype(np.int32)
        pt, _ = self.alloc.decode_tables()
        return jnp.asarray(lens), pt, jnp.asarray(kv)

    def _next_chunk(self):
        """Pick the chunk for this dispatch: up to ``prefill_chunk`` tokens
        of the OLDEST mid-prefill slot, shrunk to the per-step token
        budget (``max_step_tokens`` − live decode slots).  Returns None
        (decode-only step) when there is no prefill work or no budget —
        budget starvation is transient, since live slots drain."""
        if not self._filling:
            return None
        C = self.prefill_chunk
        n_tok = C
        if self.max_step_tokens is not None:
            n_tok = min(n_tok, self.max_step_tokens - len(self.live))
        fill = self._filling[0]
        cur = int(self.alloc.prefill_cursor[fill.slot])
        n_tok = min(n_tok, int(fill.req.tokens.shape[0]) - cur)
        if n_tok <= 0:
            return None
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n_tok] = fill.req.tokens[cur:cur + n_tok]
        return fill, cur, n_tok, chunk

    def step(self) -> None:
        """One batched dispatch: every live decode slot advances one token;
        in chunked mode one prefill chunk rides along (mixed step)."""
        positions, pt, kv = self._decode_inputs()
        work = self._next_chunk() if self.prefill_chunk is not None else None
        if work is None:
            nxt, self.pools = self._decode_step()(
                self.params, self.pools, jnp.asarray(self.tok),
                positions, pt, kv)
        else:
            fill, cur, n_tok, chunk = work
            pt_row = jnp.asarray(self.alloc.page_table[fill.slot])
            nxt, chunk_next, self.pools = self._mixed_step()(
                self.params, self.pools, jnp.asarray(self.tok),
                positions, pt, kv, jnp.asarray(chunk), pt_row,
                jnp.asarray(cur, jnp.int32), jnp.asarray(n_tok, jnp.int32))
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        self.steps += 1
        joined = -1                       # slot that turned live THIS step
        if work is not None:
            self.alloc.advance_prefill(fill.slot, n_tok)
            if not self.alloc.prefilling[fill.slot]:
                # final chunk: emit the first token (argmax after the last
                # REAL prompt position — rows ≥ n_tok are padding)
                self._filling.pop(0)
                tok0 = int(np.asarray(chunk_next)[n_tok - 1])
                st = _Live(req=fill.req, slot=fill.slot, emitted=[tok0],
                           t_last=now)
                ttft = now - (self._t0 + fill.req.arrival)
                self.latencies.append(ttft)
                self.ttfts.append(ttft)
                if fill.req.max_new == 1 or tok0 == fill.req.eos_id:
                    self._finish(st)
                else:
                    self.tok[fill.slot, 0] = tok0
                    self.live[fill.slot] = st
                    joined = fill.slot
        for slot in list(self.live):
            if slot == joined:
                continue          # first decode of this slot is next step
            st = self.live[slot]
            self.alloc.advance(slot)
            tok = int(nxt[slot, 0])
            st.emitted.append(tok)
            self.latencies.append(now - st.t_last)
            st.t_last = now
            if len(st.emitted) >= st.req.max_new or tok == st.req.eos_id:
                self._finish(st)
            else:
                self.tok[slot, 0] = tok

    # -- arrival loop -------------------------------------------------------

    def run(self, requests: List[Request]) -> Dict[str, Any]:
        """Drive the open-loop arrival trace to completion; returns
        :func:`summarize`-style metrics."""
        pending = sorted(requests, key=lambda r: r.arrival)
        self._t0 = time.perf_counter()
        i = 0
        while i < len(pending) or self.live or self._filling:
            now = time.perf_counter() - self._t0
            while i < len(pending) and pending[i].arrival <= now:
                if not self.try_admit(pending[i]):
                    break                      # no slot/pages — decode first
                i += 1
            if self.live or self._filling:
                self.step()
            elif i < len(pending):
                time.sleep(min(1e-3, max(0.0, pending[i].arrival - now)))
        wall = time.perf_counter() - self._t0
        return summarize(self.completed, self.latencies, wall,
                         steps=self.steps, ttfts=self.ttfts,
                         queue_waits=self.queue_waits,
                         compile_count=self.compile_count)


def _pctls(vals, prefix: str) -> Dict[str, Any]:
    v = np.asarray(vals, np.float64) * 1e3
    return {
        f"{prefix}_p50_ms": round(float(np.percentile(v, 50)), 3)
        if len(v) else None,
        f"{prefix}_p99_ms": round(float(np.percentile(v, 99)), 3)
        if len(v) else None,
    }


def summarize(completed: Dict[int, np.ndarray], latencies: List[float],
              wall: float, *, steps: int,
              ttfts: Optional[List[float]] = None,
              queue_waits: Optional[List[float]] = None,
              compile_count: Optional[int] = None) -> Dict[str, Any]:
    """Serving metrics.  ``latencies`` are per emitted token (TTFT for a
    request's first token, inter-token gap after); ``ttfts`` /
    ``queue_waits`` are per request — TTFT (arrival → first token) is
    where chunked prefill shows up, queue wait (arrival → admission)
    isolates capacity from prefill scheduling."""
    total = int(sum(len(v) for v in completed.values()))
    lat = np.asarray(latencies) * 1e3
    out = {
        "requests": len(completed),
        "tokens": total,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(total / wall, 2) if wall else float("inf"),
        "steps": steps,
        "p50_ms": round(float(np.percentile(lat, 50)), 3) if len(lat) else None,
        "p99_ms": round(float(np.percentile(lat, 99)), 3) if len(lat) else None,
    }
    if ttfts is not None:
        out.update(_pctls(ttfts, "ttft"))
    if queue_waits is not None:
        out.update(_pctls(queue_waits, "queue"))
    if compile_count is not None:
        out["compile_count"] = compile_count
    return out


def run_fixed_batch(model: Model, params, requests: List[Request], *,
                    batch_size: int, prompt_pad: Optional[int] = None
                    ) -> Dict[str, Any]:
    """Seed-style batch-synchronous baseline, instrumented per token.

    Requests are chunked in arrival order into fixed batches: every chunk
    waits for its LAST arrival, prompts pad to one fixed length
    (``prompt_pad``, default the max prompt in the trace — the one-shape
    compile a static serving path would pin), and the whole chunk decodes
    ``max(max_new)`` steps.  Tokens past a request's own budget are
    decoded-and-discarded — that waste, plus the arrival barrier, is
    exactly the head-of-line cost continuous batching removes.  Only the
    requested tokens count toward throughput; latencies are stamped per
    decode step, so p50/p99 compare like-for-like with the engine."""
    from .engine import _jitted_serve_step, grow_caches

    if prompt_pad is None:
        prompt_pad = max(int(r.tokens.shape[0]) for r in requests)
    step = _jitted_serve_step(model)   # lru-cached: warmup calls carry over
    reqs = sorted(requests, key=lambda r: r.arrival)
    completed: Dict[int, np.ndarray] = {}
    latencies: List[float] = []
    ttfts: List[float] = []
    queue_waits: List[float] = []
    steps = 0
    t0 = time.perf_counter()
    for c0 in range(0, len(reqs), batch_size):
        chunk = reqs[c0:c0 + batch_size]
        barrier = max(r.arrival for r in chunk)
        while time.perf_counter() - t0 < barrier:
            time.sleep(1e-3)
        now = time.perf_counter()
        for r in chunk:
            queue_waits.append(now - (t0 + r.arrival))
        toks = np.zeros((len(chunk), prompt_pad), np.int32)
        for j, r in enumerate(chunk):
            toks[j, :r.tokens.shape[0]] = r.tokens
        n_steps = max(r.max_new for r in chunk)
        logits, caches = model.prefill(params, {"tokens": jnp.asarray(toks)})
        caches = grow_caches(model, caches, len(chunk),
                             model.decode_window or prompt_pad + n_steps)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32),
                         -1)[:, None].astype(jnp.int32)
        emitted = [np.asarray(tok)[:, 0]]
        now = time.perf_counter()
        t_last = [now] * len(chunk)
        for j, r in enumerate(chunk):
            ttft = now - (t0 + r.arrival)
            latencies.append(ttft)
            ttfts.append(ttft)
        steps += 1
        for s in range(n_steps - 1):
            tok, caches = step(params, caches, tok,
                               jnp.asarray(prompt_pad + s, jnp.int32))
            tok.block_until_ready()
            now = time.perf_counter()
            steps += 1
            emitted.append(np.asarray(tok)[:, 0])
            for j, r in enumerate(chunk):
                if s + 2 <= r.max_new:      # token s+2 is within budget
                    latencies.append(now - t_last[j])
                    t_last[j] = now
        gen = np.stack(emitted, axis=1)      # (chunk, n_steps)
        for j, r in enumerate(chunk):
            completed[r.rid] = gen[j, :r.max_new]
    wall = time.perf_counter() - t0
    return summarize(completed, latencies, wall, steps=steps, ttfts=ttfts,
                     queue_waits=queue_waits)
