"""Continuous-batching decode scheduler over the paged KV cache (DESIGN §10).

The seed serving path is batch-synchronous: every request in a batch decodes
until the LAST one finishes (head-of-line blocking) and pays KV for the
longest context (padding).  Here the decode batch is a set of **slots**:
each jitted ``serve_step`` decodes every live slot in ONE dispatch, finished
requests release their pages immediately, and arrivals are admitted the
moment a slot + pages are free — so throughput tracks the *mean* request
length, not the max.

Division of labor:

* device — ``build_paged_serve_step``: embed → paged-attention block scan →
  greedy head, for the whole slot batch, jitted once (shapes are static:
  ``max_slots`` slots, fixed page-table width);
* host — :class:`ContinuousBatchingEngine`: page allocator bookkeeping,
  per-request prefill + page scatter on admit, EOS/max-token eviction, and
  the arrival loop.  Per step it ships two small int32 tables (page table,
  kv lengths) and syncs one (B, 1) token array — no cache movement.

Prefill runs per request at its EXACT prompt length (a compile per distinct
length — the load generator draws lengths from a small bucket set to bound
that).  Right-padding prompts instead would corrupt the ring-cache layout
(row = position mod window) and the last-position prefill logits.

``poisson_load`` generates open-loop Poisson arrivals with heterogeneous
prompt/output lengths; ``run_fixed_batch`` is the seed-style baseline the
benchmark gates the engine against (same step math, batch-synchronous
scheduling), instrumented per token so p50/p99 latencies are comparable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from .paged_cache import PageAllocator, PagedCacheConfig, init_paged_pools

__all__ = ["Request", "poisson_load", "build_paged_serve_step",
           "ContinuousBatchingEngine", "run_fixed_batch", "summarize"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (S,) int32 prompt ids
    max_new: int                # generation budget incl. the prefill token
    arrival: float              # seconds after load start (open loop)
    eos_id: int = -1            # -1: disabled (random-weight smokes)


def poisson_load(n_requests: int, rate: float, *, vocab: int,
                 prompt_buckets=(16, 32), new_token_buckets=(8, 16, 32, 96),
                 seed: int = 0, eos_id: int = -1) -> List[Request]:
    """Open-loop Poisson arrivals (exponential gaps at ``rate`` req/s) with
    prompt lengths and generation budgets drawn uniformly from small bucket
    sets — heterogeneous enough to expose head-of-line blocking, bucketed
    so prefill compiles stay bounded."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        S = int(rng.choice(prompt_buckets))
        out.append(Request(
            rid=rid,
            tokens=rng.integers(0, vocab, (S,)).astype(np.int32),
            max_new=int(rng.choice(new_token_buckets)),
            arrival=t, eos_id=eos_id))
    return out


def build_paged_serve_step(model: Model, *, attn_impl: str = "ref",
                           page_size: Optional[int] = None) -> Callable:
    """jitted ``step(params, pools, token, positions, page_table, kv_len)``
    → ``(next_token (B, 1), new_pools)``: one dispatch decodes the whole
    slot batch through the paged cache (greedy head).

    ``attn_impl``: "ref" is the pure-jnp gather + ``sdpa_ref`` path — the
    bit-exactness anchor the divergence gate relies on; "pallas" reads the
    page pool directly through :func:`repro.kernels.ops.paged_attention`
    (page-table gather in the BlockSpec index map, no dense gather)."""
    assert model.decode_step_paged is not None, \
        f"{model.cfg.family}: no paged decode path (attention families only)"
    if attn_impl == "ref":
        attn_fn = None
    else:
        assert attn_impl == "pallas" and page_size is not None
        from repro.kernels.ops import paged_attention

        def attn_fn(q, k_pool, v_pool, page_table, kv_len):
            return paged_attention(q, k_pool, v_pool, page_table, kv_len,
                                   page_size=page_size)

    def step(params, pools, token, positions, page_table, kv_len):
        logits, pools = model.decode_step_paged(
            params, pools, token, positions, page_table, kv_len,
            attn_fn=attn_fn)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return nxt.astype(jnp.int32)[:, None], pools

    return jax.jit(step)


@dataclasses.dataclass
class _Live:
    req: Request
    slot: int
    emitted: List[int]
    t_last: float               # emission time of the latest token


class ContinuousBatchingEngine:
    """Slot-based continuous batching: admit on free pages, decode every
    live slot per dispatch, evict on EOS/max-tokens.

    Greedy decoding on the ``attn_impl="ref"`` backend is **token-exact**
    vs the dense reference :func:`repro.serve.engine.greedy_generate`:
    identical q/k/v values flow through the same ``sdpa_ref`` ops, and
    page-padding columns contribute exactly 0.0 under softmax
    (``exp(-1e30 − m)`` underflows to 0.0, and adding 0.0 to a float sum
    is the identity).  Logits agree to float32 rounding — the padded
    attention width changes XLA's reduction splitting, so the last ulp
    can wiggle without ever moving the argmax — see ``tests/test_serve.py``.
    """

    def __init__(self, model: Model, params, pcfg: PagedCacheConfig, *,
                 attn_impl: str = "ref"):
        assert model.decode_window == pcfg.window, \
            (model.decode_window, pcfg.window)
        self.model, self.params, self.pcfg = model, params, pcfg
        self.alloc = PageAllocator(pcfg)
        self.pools = init_paged_pools(model.cfg, pcfg)
        self._step = build_paged_serve_step(model, attn_impl=attn_impl,
                                            page_size=pcfg.page_size)
        self._prefill = jax.jit(model.prefill)
        self._scatter = jax.jit(self._scatter_impl)
        self.reset()

    def reset(self) -> None:
        """Fresh serving state (allocator, slots, metrics) with the jitted
        step/prefill/scatter callables retained — benchmarks warm up the
        compiles on a throwaway trace, reset, then measure.  Pools keep
        stale pages: every page is re-written (prefill scatter / decode
        write) before ``kv_len`` ever exposes it, so stale rows are
        unreachable by construction (the masked-tail contract)."""
        pcfg = self.pcfg
        self.alloc = PageAllocator(pcfg)
        if not hasattr(self, "pools"):
            self.pools = init_paged_pools(self.model.cfg, pcfg)
        self.tok = np.zeros((pcfg.max_slots, 1), np.int32)
        self.live: Dict[int, _Live] = {}          # slot -> state
        self.completed: Dict[int, np.ndarray] = {}  # rid -> generated ids
        self.latencies: List[float] = []          # per emitted token (s)
        self.steps = 0
        self._t0 = time.perf_counter()            # run() resets; absolute

    # -- device helpers -----------------------------------------------------

    @staticmethod
    def _scatter_impl(pools, caches, pages):
        """Scatter one request's dense prefill cache into its pages.
        caches leaf: (n_blocks, 1, L, K, hd); pages: (n_used,) physical
        ids.  Logical row r lands at row ``r % page_size`` of page
        ``pages[r // page_size]`` — for ring caches L == window and the
        rolled prefill layout maps through unchanged."""

        def one(pool, c):
            n_blocks, _, L, K, hd = c.shape
            ps = pool.shape[2]
            n_used = pages.shape[0]
            rows = jnp.pad(c[:, 0], ((0, 0), (0, n_used * ps - L),
                                     (0, 0), (0, 0)))
            rows = rows.reshape(n_blocks, n_used, ps, K, hd)
            return pool.at[:, pages].set(rows)

        return jax.tree.map(one, pools, caches)

    # -- admission / eviction -----------------------------------------------

    def try_admit(self, req: Request) -> bool:
        """Prefill + page scatter if a slot and enough pages are free.
        Emits the request's first token (prefill argmax)."""
        S = int(req.tokens.shape[0])
        # rows the slot will hold: prompt + every fed-back token (the
        # final emitted token is never fed, hence max_new − 1)
        ctx = S + req.max_new - 1
        if not self.alloc.can_admit(ctx):
            return False
        slot = self.alloc.admit(ctx, S)
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(req.tokens)[None]})
        n_used = self.alloc.pages_needed(ctx)
        pages = jnp.asarray(self.alloc.page_table[slot, :n_used])
        self.pools = self._scatter(self.pools, caches, pages)
        tok0 = int(jnp.argmax(logits[0, -1].astype(jnp.float32)))
        now = time.perf_counter()
        st = _Live(req=req, slot=slot, emitted=[tok0], t_last=now)
        # TTFT of token #1 (queue wait + prefill), on the absolute clock
        self.latencies.append(now - (self._t0 + req.arrival))
        if req.max_new == 1 or tok0 == req.eos_id:
            self._finish(st)
        else:
            self.tok[slot, 0] = tok0
            self.live[slot] = st
        return True

    def _finish(self, st: _Live) -> None:
        self.completed[st.req.rid] = np.asarray(st.emitted, np.int32)
        self.alloc.release(st.slot)
        self.tok[st.slot, 0] = 0
        self.live.pop(st.slot, None)

    # -- decode -------------------------------------------------------------

    def step(self) -> None:
        """One batched decode dispatch over every live slot."""
        lens = self.alloc.lengths
        active = self.alloc.active
        kv = np.where(active, lens + 1, 0).astype(np.int32)
        if self.pcfg.window:
            kv = np.minimum(kv, self.pcfg.window).astype(np.int32)
        pt, _ = self.alloc.device_tables()
        nxt, self.pools = self._step(
            self.params, self.pools, jnp.asarray(self.tok),
            jnp.asarray(lens), pt, jnp.asarray(kv))
        nxt = np.asarray(nxt)
        now = time.perf_counter()
        self.steps += 1
        for slot in list(self.live):
            st = self.live[slot]
            self.alloc.advance(slot)
            tok = int(nxt[slot, 0])
            st.emitted.append(tok)
            self.latencies.append(now - st.t_last)
            st.t_last = now
            if len(st.emitted) >= st.req.max_new or tok == st.req.eos_id:
                self._finish(st)
            else:
                self.tok[slot, 0] = tok

    # -- arrival loop -------------------------------------------------------

    def run(self, requests: List[Request]) -> Dict[str, Any]:
        """Drive the open-loop arrival trace to completion; returns
        :func:`summarize`-style metrics."""
        pending = sorted(requests, key=lambda r: r.arrival)
        self._t0 = time.perf_counter()
        i = 0
        while i < len(pending) or self.live:
            now = time.perf_counter() - self._t0
            while i < len(pending) and pending[i].arrival <= now:
                if not self.try_admit(pending[i]):
                    break                      # no slot/pages — decode first
                i += 1
            if self.live:
                self.step()
            elif i < len(pending):
                time.sleep(min(1e-3, max(0.0, pending[i].arrival - now)))
        wall = time.perf_counter() - self._t0
        return summarize(self.completed, self.latencies, wall,
                         steps=self.steps)


def summarize(completed: Dict[int, np.ndarray], latencies: List[float],
              wall: float, *, steps: int) -> Dict[str, Any]:
    total = int(sum(len(v) for v in completed.values()))
    lat = np.asarray(latencies) * 1e3
    return {
        "requests": len(completed),
        "tokens": total,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(total / wall, 2) if wall else float("inf"),
        "steps": steps,
        "p50_ms": round(float(np.percentile(lat, 50)), 3) if len(lat) else None,
        "p99_ms": round(float(np.percentile(lat, 99)), 3) if len(lat) else None,
    }


def run_fixed_batch(model: Model, params, requests: List[Request], *,
                    batch_size: int, prompt_pad: Optional[int] = None
                    ) -> Dict[str, Any]:
    """Seed-style batch-synchronous baseline, instrumented per token.

    Requests are chunked in arrival order into fixed batches: every chunk
    waits for its LAST arrival, prompts pad to one fixed length
    (``prompt_pad``, default the max prompt in the trace — the one-shape
    compile a static serving path would pin), and the whole chunk decodes
    ``max(max_new)`` steps.  Tokens past a request's own budget are
    decoded-and-discarded — that waste, plus the arrival barrier, is
    exactly the head-of-line cost continuous batching removes.  Only the
    requested tokens count toward throughput; latencies are stamped per
    decode step, so p50/p99 compare like-for-like with the engine."""
    from .engine import _jitted_serve_step, grow_caches

    if prompt_pad is None:
        prompt_pad = max(int(r.tokens.shape[0]) for r in requests)
    step = _jitted_serve_step(model)   # lru-cached: warmup calls carry over
    reqs = sorted(requests, key=lambda r: r.arrival)
    completed: Dict[int, np.ndarray] = {}
    latencies: List[float] = []
    steps = 0
    t0 = time.perf_counter()
    for c0 in range(0, len(reqs), batch_size):
        chunk = reqs[c0:c0 + batch_size]
        barrier = max(r.arrival for r in chunk)
        while time.perf_counter() - t0 < barrier:
            time.sleep(1e-3)
        toks = np.zeros((len(chunk), prompt_pad), np.int32)
        for j, r in enumerate(chunk):
            toks[j, :r.tokens.shape[0]] = r.tokens
        n_steps = max(r.max_new for r in chunk)
        logits, caches = model.prefill(params, {"tokens": jnp.asarray(toks)})
        caches = grow_caches(model, caches, len(chunk),
                             model.decode_window or prompt_pad + n_steps)
        tok = jnp.argmax(logits[:, -1].astype(jnp.float32),
                         -1)[:, None].astype(jnp.int32)
        emitted = [np.asarray(tok)[:, 0]]
        now = time.perf_counter()
        t_last = [now] * len(chunk)
        for j, r in enumerate(chunk):
            latencies.append(now - (t0 + r.arrival))
        steps += 1
        for s in range(n_steps - 1):
            tok, caches = step(params, caches, tok,
                               jnp.asarray(prompt_pad + s, jnp.int32))
            tok.block_until_ready()
            now = time.perf_counter()
            steps += 1
            emitted.append(np.asarray(tok)[:, 0])
            for j, r in enumerate(chunk):
                if s + 2 <= r.max_new:      # token s+2 is within budget
                    latencies.append(now - t_last[j])
                    t_last[j] = now
        gen = np.stack(emitted, axis=1)      # (chunk, n_steps)
        for j, r in enumerate(chunk):
            completed[r.rid] = gen[j, :r.max_new]
    wall = time.perf_counter() - t0
    return summarize(completed, latencies, wall, steps=steps)
