"""repro.train — decentralized training loop substrate."""
from .trainer import (  # noqa: F401
    Features, TrainState, batch_spec_tree, build_train_step, bus_layout_for,
    gossip_round_step, init_state, make_gossip_schedule, make_group_plans,
    make_topology, prepend_agent_axis, resolve_features, resolve_group_specs,
    state_specs, use_overlap, use_packed_bus, use_wire,
)
from . import checkpoint  # noqa: F401
