"""repro.train — decentralized training loop substrate."""
from .trainer import (  # noqa: F401
    TrainState, batch_spec_tree, build_train_step, gossip_round_step,
    init_state, make_gossip_schedule, make_topology, prepend_agent_axis,
    state_specs,
)
from . import checkpoint  # noqa: F401
