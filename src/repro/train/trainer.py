"""Decentralized trainer: EDM (or any registered algorithm) over a model.

The train state carries the full per-agent replica set:
    params : every leaf (A, *shape)   — A = number of agents
    opt    : algorithm state (same leading axis)
    step   : scalar

``build_train_step`` returns a pure function suitable for jax.jit with
explicit in/out shardings (see :func:`state_specs`).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core import (GossipSchedule, GroupPlan, StaticSchedule, Topology,
                        accumulate_f32, build_mixer, make_codec,
                        make_edm_bus, make_edm_bus_ef, make_group_mixer,
                        make_optimizer, make_schedule)
from repro.core.optimizers import DecOptimizer
from repro.core.wire import WIRE_FORMATS, encode_ef
from repro.core import bus as parambus
from repro.core.bus import GroupSpec
from repro.core.metrics import bus_consensus, bus_grad_norm, consensus_distance
from repro.models.api import Model
from repro.optim import scale_grads, warmup_cosine

__all__ = [
    "TrainState", "build_train_step", "init_state", "state_specs",
    "make_topology", "make_gossip_schedule", "gossip_round_step",
    "prepend_agent_axis", "batch_spec_tree", "Features", "resolve_features",
    "resolve_group_specs", "make_group_plans", "use_packed_bus",
    "use_overlap", "use_wire", "bus_layout_for",
]


TrainState = Dict[str, Any]  # {"params", "opt", "step"}


def make_topology(run: RunConfig, n_agents: int, pods: int = 1) -> Topology:
    from repro.core import exp_graph, fully_connected, hierarchical, ring, torus2d
    if run.topology == "ring":
        return ring(n_agents)
    if run.topology == "exp":
        return exp_graph(n_agents)
    if run.topology == "full":
        return fully_connected(n_agents)
    if run.topology == "torus":
        return torus2d(pods if pods > 1 else 1, n_agents // max(pods, 1))
    if run.topology == "hier":
        assert pods >= 1
        return hierarchical(pods, n_agents // pods)
    raise ValueError(run.topology)


def make_gossip_schedule(run: RunConfig, n_agents: int, pods: int = 1,
                         churn=None) -> GossipSchedule:
    """``RunConfig`` → step-indexed gossip schedule (DESIGN §4).

    ``gossip_schedule="static"`` wraps :func:`make_topology`'s W;
    ``"round_robin"`` / ``"alt_hier"`` build the time-varying schedules
    (``gossip_period``/``gossip_seed`` are their knobs).

    ``churn`` (DESIGN §8) wraps the result in an
    :class:`~repro.core.elastic.ElasticSchedule`: a
    :class:`~repro.core.elastic.DropPlan`, or anything
    ``DropPlan.from_json`` accepts (path, inline JSON, dict).  The
    degraded schedule re-checks Assumption 1 per liveness epoch here, so
    a plan that breaks mixing fails at build time, not mid-run.
    """
    topo = (make_topology(run, n_agents, pods)
            if run.gossip_schedule in ("static", "", None) else None)
    sched = make_schedule(run.gossip_schedule, n_agents, topo=topo, pods=pods,
                          period=run.gossip_period, seed=run.gossip_seed)
    if churn is not None:
        from repro.core import DropPlan, ElasticSchedule
        plan = (churn if isinstance(churn, DropPlan)
                else DropPlan.from_json(churn))
        sched = ElasticSchedule(sched, plan)
        sched.check_assumption1()
    return sched


def gossip_round_step(step, gossip_every: int):
    """Round-index clock for the gossip schedule.

    With ``gossip_every=k > 1`` gossip only executes on steps ≡ k−1 (mod k);
    indexing the schedule by the raw step would then alias against the
    period (any gcd(k, period) > 1 runs only a strict subset of rounds —
    e.g. k=5 on the n=32 round-robin schedule would gossip over offset 16
    forever, never reaching consensus).  Advance the schedule per *executed
    gossip* instead: round = (step // k) mod period cycles through every
    round regardless of k.
    """
    return step // gossip_every if gossip_every > 1 else step


@dataclasses.dataclass(frozen=True)
class Features:
    """The resolved feature matrix of a :class:`RunConfig` — what the
    train step will actually run (DESIGN §5/§6/§9/§12 fallback matrix,
    validated in ONE place by :func:`resolve_features`).

    ``packed_bus``: bus-resident EDM step.  ``overlap``: the delayed
    gossip pipeline.  ``wire``: the run-level error-feedback wire format
    ("f32" = byte-identical legacy wire).  ``groups``: the policy-group
    specs (empty tuple = the single default "dense" group, bit-identical
    to the ungrouped bus)."""

    packed_bus: bool
    overlap: bool
    wire: str
    groups: Tuple[GroupSpec, ...] = ()

    @property
    def grouped(self) -> bool:
        return bool(self.groups)


def resolve_group_specs(run: RunConfig) -> Tuple[GroupSpec, ...]:
    """Parse ``RunConfig.gossip_groups`` into :class:`GroupSpec`s.

    Accepts ``""`` (no groups — the default single-group bus), a JSON
    list (the ``--gossip-groups`` CLI payload, see
    :func:`repro.core.bus.group_specs_from_json`), or comma-separated
    presets: ``moe[:k]`` (expert leaves, default opt-out k=0) and
    ``ssm[:k]`` (conv/SSM state leaves, default local-only k=0) — ``k``
    is the group's ``gossip_every`` (0 = never gossip, k>1 slow-cycle).
    """
    spec = (run.gossip_groups or "").strip()
    if not spec:
        return ()
    if spec.startswith("["):
        return parambus.group_specs_from_json(json.loads(spec))
    specs = []
    for tok in spec.split(","):
        name, _, every = tok.strip().partition(":")
        k = int(every) if every else 0
        if name == "moe":
            from repro.models.moe import expert_group_spec
            specs.append(expert_group_spec(gossip_every=k))
        elif name == "ssm":
            from repro.models.mamba import ssm_state_group_spec
            specs.append(ssm_state_group_spec(gossip_every=k))
        else:
            raise AssertionError(
                f"unknown gossip-groups preset {name!r}: expected 'moe[:k]',"
                " 'ssm[:k]', or a JSON list of group specs "
                '([{"name": ..., "match": [...], "gossip_every": ..., '
                '"wire": ...}, ...])')
    return tuple(specs)


def resolve_features(run: RunConfig) -> Features:
    """Resolve a :class:`RunConfig` to its :class:`Features` — THE
    validation point for the feature compatibility matrix.

    * packed bus (DESIGN §5): explicit ``run.packed_bus`` wins; the None
      default turns it on for the production ``algorithm="edm"`` +
      ``gossip_engine="ppermute"`` combination.  Requires
      ``algorithm="edm"`` and ``agents in ("data", "pod")``.
    * overlap (DESIGN §6): ``"delayed"`` needs the packed bus (ONE
      in-flight buffer), ``gossip_every == 1`` (a payload in flight every
      step) and no ``gossip_dtype`` cast.
    * wire (DESIGN §9): bf16/int8 need the packed bus (bus-shaped EF
      residual) and exclude the ``gossip_dtype`` cast lever.
    * policy groups (DESIGN §12): need the packed bus (groups are row
      ranges of the superbuffer), run-level ``gossip_every == 1`` (the
      cadence moves into each group), an f32 run-level wire (per-group
      wire formats are stateless; the EF residual is a whole-bus,
      single-group feature), no overlap, and no ``gossip_dtype`` cast.

    Every violation raises with the lever to flip.  The legacy
    ``use_packed_bus`` / ``use_overlap`` / ``use_wire`` helpers are thin
    deprecated wrappers over this function.
    """
    if run.packed_bus is not None:
        packed = run.packed_bus
        if packed:
            assert run.algorithm == "edm", \
                f"packed_bus supports algorithm='edm', got " \
                f"{run.algorithm!r} — unset packed_bus or switch algorithm"
            assert run.agents in ("data", "pod"), \
                f"packed_bus supports agents='data'|'pod', got {run.agents!r}"
    else:
        packed = (run.algorithm == "edm" and run.gossip_engine == "ppermute"
                  and run.agents in ("data", "pod"))

    if run.overlap in ("off", "", None):
        overlap = False
    else:
        assert run.overlap == "delayed", \
            f"RunConfig.overlap must be 'off' or 'delayed', got " \
            f"{run.overlap!r}"
        assert packed, \
            "overlap='delayed' needs the packed bus (DESIGN §6): the " \
            "in-flight payload is one (A, rows, 128) buffer, not a leaf " \
            "set — use algorithm='edm' with gossip_engine='ppermute' or " \
            "packed_bus=True"
        assert run.gossip_every == 1, \
            "overlap='delayed' composes with gossip_every=1 only (the " \
            "pipeline keeps a payload in flight every step)"
        assert run.gossip_dtype in ("float32", "", None), \
            "overlap='delayed' rejects the gossip_dtype cast lever (a " \
            "synchronous-path lever; use the error-feedback wire codec " \
            "RunConfig.wire instead — it composes, DESIGN §6/§9 fallback " \
            "matrix)"
        overlap = True

    fmt = run.wire or "f32"
    assert fmt in WIRE_FORMATS, \
        f"RunConfig.wire must be one of {WIRE_FORMATS}, got {fmt!r}"
    if fmt != "f32":
        assert packed, \
            "wire != 'f32' needs the packed bus (DESIGN §9): the codec " \
            "and the bus-resident residual operate on the (A, rows, 128) " \
            "superbuffer"
        assert run.gossip_dtype in ("float32", "", None), \
            "wire != 'f32' is mutually exclusive with gossip_dtype != " \
            "float32 (the error-feedback codec replaces the cast-on-wire " \
            "lever)"

    groups = resolve_group_specs(run)
    if groups:
        assert packed, \
            "gossip_groups need the packed bus (DESIGN §12): policy " \
            "groups are row ranges of the (A, rows, 128) superbuffer — " \
            "use algorithm='edm' with gossip_engine='ppermute' or " \
            "packed_bus=True"
        assert run.gossip_every == 1, \
            "gossip_groups replace the run-level gossip_every: set " \
            "gossip_every=1 and put the cadence on each group's " \
            "gossip_every instead (DESIGN §12)"
        assert not overlap, \
            "gossip_groups do not compose with overlap='delayed' yet (the " \
            "pipeline carries ONE whole-bus payload; per-group staleness " \
            "is future work) — run overlap='off'"
        assert fmt == "f32", \
            "gossip_groups exclude the run-level error-feedback wire " \
            "(the EF residual is whole-bus); set per-group wire formats " \
            "in the group specs instead (stateless quantization)"
        assert run.gossip_dtype in ("float32", "", None), \
            "gossip_groups exclude the gossip_dtype cast lever; set " \
            "per-group wire formats in the group specs instead"
    return Features(packed, overlap, fmt, groups)


def use_packed_bus(run: RunConfig) -> bool:
    """Deprecated: use :func:`resolve_features`\\ ``(run).packed_bus``."""
    warnings.warn("use_packed_bus(run) is deprecated; use "
                  "resolve_features(run).packed_bus", DeprecationWarning,
                  stacklevel=2)
    return resolve_features(run).packed_bus


def use_overlap(run: RunConfig) -> bool:
    """Deprecated: use :func:`resolve_features`\\ ``(run).overlap``."""
    warnings.warn("use_overlap(run) is deprecated; use "
                  "resolve_features(run).overlap", DeprecationWarning,
                  stacklevel=2)
    return resolve_features(run).overlap


def use_wire(run: RunConfig) -> str:
    """Deprecated: use :func:`resolve_features`\\ ``(run).wire``."""
    warnings.warn("use_wire(run) is deprecated; use "
                  "resolve_features(run).wire", DeprecationWarning,
                  stacklevel=2)
    return resolve_features(run).wire


def bus_layout_for(model: Model, n_agents: int, shards: int = 1,
                   groups: Tuple[GroupSpec, ...] = ()) -> parambus.BusLayout:
    """Cached bus layout of ``model``'s parameter tree with a leading agent
    axis — the single layout object shared by ``init_state``, the train
    step and checkpointing (shape-only, no allocation).  ``shards`` is the
    FSDP row-shard count of the shard-resident mode (DESIGN §7);
    ``groups`` the policy-group specs (DESIGN §12, usually
    ``resolve_features(run).groups``)."""
    return parambus.layout_of(model, n_agents, shards=shards,
                              groups=tuple(groups) or None)


def make_group_plans(run: RunConfig, layout: parambus.BusLayout,
                     sched: GossipSchedule, pods: int = 1):
    """Resolve a grouped layout into per-group :class:`GroupPlan`s.

    Every gossiping group gets its schedule — the run's ``sched`` unless
    the group names an override — and **Assumption 1 is re-checked per
    group** (each group's round sequence must be doubly stochastic with
    positive diagonal and a positive period-product spectral gap on the
    gossiping block); a policy that breaks mixing for any group fails at
    build time.  Opt-out groups (``gossip_every == 0``) carry no schedule
    and no codec — the group mixer never builds collectives for their
    rows.  Per-group wire formats resolve to stateless codecs on the
    layout's block grid.
    """
    plans = []
    for g in layout.groups:
        if g.gossip_every == 0 or g.rows == 0:
            plans.append(GroupPlan(g, None, None))
            continue
        gsched = sched
        if g.schedule:
            grun = dataclasses.replace(run, gossip_schedule=g.schedule)
            gsched = make_gossip_schedule(grun, sched.n_agents, pods)
        gsched.check_assumption1()
        codec = (make_codec(g.wire, layout.block_rows)
                 if g.wire != "f32" else None)
        plans.append(GroupPlan(g, gsched, codec))
    return plans


def _cast_mixer(mix, dtype: Optional[str]):
    """Optionally gossip in a lower-precision payload (§Perf lever);
    ``accumulate_f32`` restores the original leaf dtypes on the way out."""
    if not dtype or dtype == "float32":
        return mix
    dt = jnp.dtype(dtype)
    return accumulate_f32(
        lambda tree: mix(jax.tree.map(lambda x: x.astype(dt), tree)))


def build_train_step(model: Model, run: RunConfig, topo,
                     use_fused_kernel: bool = False, mesh=None,
                     agent_axes=None, shard_axes=None,
                     straggler_plan=None, pods: int = 1) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves: (A, per_agent_batch, ...).

    ``topo`` is a :class:`Topology` (wrapped into a period-1
    :class:`StaticSchedule`) or a :class:`GossipSchedule`; ``state["step"]``
    is threaded into the mixer so step t gossips over round t mod period —
    and because the mixer is bound per step, EDM's bias-corrected payload
    φ = ψ' + x − ψ is mixed with the *same* round's W that defines the
    step's combine, keeping the exact-diffusion consistency per step
    (DESIGN §4).

    ``run.gossip_engine`` selects the mixing engine; the ppermute engine
    additionally needs ``mesh``/``agent_axes`` (an agent block per mesh
    slice, see DESIGN §3–4) and honors ``use_fused_kernel`` for its combine,
    so ``engine="ppermute"`` + ``use_fused_kernel=True`` composes the fused
    gossip path with the fused EDM update end-to-end.

    With the packed bus active (:func:`use_packed_bus`, DESIGN §5) the step
    runs **bus-resident**: ``state["params"]`` / ``state["opt"]`` are
    ``(A, rows, 128)`` superbuffers, the tree is unpacked only for
    loss/grad, the EDM update is ONE kernel over the whole bus and the
    gossip ships one payload per term.  Jit the returned function with
    ``donate_argnums=(0,)`` so XLA aliases the bus buffers in place.

    With ``run.overlap="delayed"`` (:func:`use_overlap`, DESIGN §6) the
    step is restructured into **issue → compute → complete** phases: the
    live double-buffered payload φ(t) (``state["pipeline"]``) has its
    gossip permutes issued *before* the backward pass, gradients are
    evaluated at the pre-mix iterate φ(t) (the one-step-stale-mixing
    variant of EDM), and the combine + EDM update run after — so the wire
    sits in the backward pass's shadow instead of on the critical path.
    ``overlap="off"`` is bit-identical to the synchronous bus step.

    With ``shard_axes`` set (``agents="pod"`` + FSDP, DESIGN §7) the bus's
    row axis is sharded over that mesh axis: the gossip permutes, the
    combine and the fused EDM update all run on each shard's own row block
    (the fused kernel is shard_map-wrapped so XLA never gathers the bus
    around an unpartitioned pallas_call), and every bus-shaped
    intermediate is pinned to the ``P(agent_axes, shard_axes)`` sharding.

    ``straggler_plan`` (a :class:`~repro.core.elastic.StragglerPlan`,
    DESIGN §8) composes with the overlap pipeline only: each step's late
    slot mask is threaded into ``complete``, degrading late gossip terms
    to self-weight instead of blocking on their payloads.  Churn rides in
    through ``topo`` itself — hand an
    :class:`~repro.core.elastic.ElasticSchedule` and every engine applies
    the liveness-degraded round of the step's epoch.
    """
    sched = topo if isinstance(topo, GossipSchedule) else StaticSchedule(topo)
    feats = resolve_features(run)
    overlap = feats.overlap
    kw = dict(use_fused_kernel=use_fused_kernel) if run.algorithm == "edm" else {}
    packed = feats.packed_bus
    shards = 1
    bus_spec = None
    if shard_axes is not None:
        assert packed, "shard_axes composes with the packed bus only"
        assert mesh is not None and agent_axes is not None, \
            "shard-resident gossip needs mesh= and agent_axes="
        shards = int(mesh.shape[shard_axes])
        agent_entry = (tuple(agent_axes)
                       if isinstance(agent_axes, (tuple, list)) else agent_axes)
        bus_spec = P(agent_entry, shard_axes)
    layout = (bus_layout_for(model, sched.n_agents, shards=shards,
                             groups=feats.groups)
              if packed else None)
    grouped = packed and layout.is_grouped
    wire_fmt = feats.wire
    # the codec's int8 scale blocks ARE the layout's (block_rows, 128) grid
    # tiles, and rows is a multiple of block_rows × shards — shard-local
    # encode/decode by construction (DESIGN §9).
    codec = (make_codec(wire_fmt, layout.block_rows)
             if packed and wire_fmt != "f32" else None)

    def pin_bus(b):
        """Keep bus-shaped intermediates row-sharded (no-op off pod mode)."""
        if bus_spec is None:
            return b
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            b, NamedSharding(mesh, bus_spec))

    fused_update = None
    fused_update_ef = None
    if packed and shard_axes is not None and use_fused_kernel:
        # shard-local fused EDM update: one pallas_call per shard over its
        # own (A_local, rows/S, 128) block — griddable by layout contract.
        from repro.compat import shard_map as _shard_map
        from repro.kernels import ops as kops

        def fused_update(x, g, m, psi):
            body = functools.partial(kops.edm_update_bus, alpha=run.alpha,
                                     beta=run.beta,
                                     block_rows=layout.block_rows)
            return _shard_map(body, mesh, (bus_spec,) * 4,
                              (bus_spec,) * 3)(x, g, m, psi)

        if codec is not None:
            # shard-local fused EDM + EF quantize: the payload out-specs
            # mirror the codec pytree (int8 scales are (A, nb) row-sharded
            # like the bus — whole scale blocks per shard by layout).
            pay_spec = ((bus_spec, bus_spec) if codec.fmt == "int8"
                        else bus_spec)

            def fused_update_ef(x, g, m, psi, e):
                body = functools.partial(kops.edm_update_bus_ef,
                                         alpha=run.alpha, beta=run.beta,
                                         fmt=codec.fmt,
                                         block_rows=layout.block_rows)
                return _shard_map(body, mesh, (bus_spec,) * 5,
                                  (bus_spec, bus_spec, pay_spec,
                                   bus_spec))(x, g, m, psi, e)

    base_mix = None
    if grouped:
        # group-aware bus mixer (DESIGN §12): one permute plan per active
        # group per step — opt-out rows never touch a collective, and each
        # group runs its own cadence / schedule / wire codec.  Assumption 1
        # is re-checked per group inside make_group_plans.
        base_mix = make_group_mixer(
            make_group_plans(run, layout, sched, pods),
            engine=run.gossip_engine, mesh=mesh, agent_axes=agent_axes,
            use_fused_kernel=use_fused_kernel, shard_axes=shard_axes)
    elif not overlap:
        base_mix = build_mixer(
            sched, mode="schedule", engine=run.gossip_engine, mesh=mesh,
            agent_axes=agent_axes, use_fused_kernel=use_fused_kernel,
            shard_axes=shard_axes, wire=codec)

    def opt_at(step, mix_override=None):
        """Algorithm with the mixer bound to ``step``'s gossip round (the
        bus-resident EDM when the packed bus is active; its EF-compressed
        variant when a wire codec is active, DESIGN §9)."""
        if packed and codec is not None:
            if mix_override is not None:
                # gossip-skipped local step (gossip_every > 1): plain EDM
                # recursion, nothing on the wire, so nothing is quantized
                # and the residual carries untouched to the next gossiping
                # step (cross-round carry, DESIGN §9).
                inner = make_edm_bus(run.alpha, run.beta, mix_override,
                                     block_rows=layout.block_rows,
                                     use_fused_kernel=use_fused_kernel,
                                     update=fused_update)

                def local_step(x, g, st):
                    x2, sub = inner.step(x, g, {"m": st["m"],
                                                "psi": st["psi"]})
                    return x2, {**sub, "e": st["e"]}

                return DecOptimizer("edm_bus_local", inner.init, local_step)
            return make_edm_bus_ef(run.alpha, run.beta,
                                   functools.partial(base_mix, step=step),
                                   codec, block_rows=layout.block_rows,
                                   use_fused_kernel=use_fused_kernel,
                                   update=fused_update_ef)
        mix = mix_override if mix_override is not None else _cast_mixer(
            functools.partial(base_mix, step=step), run.gossip_dtype)
        if packed:
            return make_edm_bus(run.alpha, run.beta, mix,
                                block_rows=layout.block_rows,
                                use_fused_kernel=use_fused_kernel,
                                update=fused_update)
        return make_optimizer(run.algorithm, alpha=run.alpha, beta=run.beta,
                              mix=mix, **kw)

    def agent_loss(params, batch):
        kw = {}
        if model.cfg.family != "encdec":
            kw["remat_policy"] = run.remat_policy
        return model.loss(params, batch, remat=run.remat, **kw)

    grad_fn = jax.vmap(jax.value_and_grad(agent_loss))

    lr_sched = None
    if run.warmup_steps or run.total_steps:
        lr_sched = warmup_cosine(run.warmup_steps or 1,
                                 run.total_steps or 10**9)

    def scaled_grads(grads, step):
        """LR schedule as gradient scaling — the one call site both the
        synchronous and the overlapped step share."""
        if lr_sched is None:
            return grads
        return scale_grads(grads, step, lr_sched)

    assert straggler_plan is None or overlap, \
        "straggler_plan composes with overlap='delayed' only (the " \
        "synchronous step has no payload stack to degrade)"

    if overlap:
        issue, complete = build_mixer(
            sched, mode="overlap", engine=run.gossip_engine, mesh=mesh,
            agent_axes=agent_axes, use_fused_kernel=use_fused_kernel,
            shard_axes=shard_axes, wire=codec)
        if straggler_plan is not None:
            assert straggler_plan.n_terms == complete.n_terms, \
                f"StragglerPlan.n_terms={straggler_plan.n_terms} must match " \
                f"the overlap payload stack arity K={complete.n_terms}"
        # the delayed pipeline mixes FIRST (the in-flight payload), then
        # runs the local EDM recursion on the mixed iterate — so the
        # optimizer's own mix is the identity and the wire lives in the
        # issue/complete phases around the backward pass.
        local_opt = make_edm_bus(run.alpha, run.beta, mix=lambda t: t,
                                 block_rows=layout.block_rows,
                                 use_fused_kernel=use_fused_kernel,
                                 update=fused_update)

        def encode_pipeline(c):
            """Issue-time EF encode of the corrected payload c = φ + e
            (DESIGN §9: quantize at issue time, residual accounted at
            complete time).  Shard_map-wrapped in shard-resident mode so
            the per-block reductions never tempt GSPMD into a gather."""
            if bus_spec is None:
                return encode_ef(codec, c)
            from repro.compat import shard_map as _shard_map
            pay_spec = ((bus_spec, bus_spec) if codec.fmt == "int8"
                        else bus_spec)
            return _shard_map(functools.partial(encode_ef, codec), mesh,
                              (bus_spec,), (pay_spec, bus_spec))(c)

        def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
            pipe = state["pipeline"]
            phi = parambus.pipeline_payload(pipe)
            g_step = state["step"]          # gossip_every == 1 under overlap
            # ISSUE: put the round's permutes of φ(t) on the wire — nothing
            # below until `complete` depends on them.  With a wire codec the
            # payload is quantized HERE (φ(t) + e(t) encoded, residual split
            # off), so the in-flight bytes are already compressed; the
            # pipeline buffer itself stays f32 (checkpoint/resize shapes are
            # wire-independent).
            if codec is not None:
                c = pin_bus(phi + state["opt"]["e"])
                enc, e_new = encode_pipeline(c)
                payloads = issue(enc, g_step)
            else:
                payloads = issue(phi, g_step)
            # COMPUTE: gradients at the pre-mix local iterate φ(t); the
            # whole fwd/bwd is independent of the in-flight permutes.
            params_tree = parambus.unpack_tree(layout, phi)
            losses, grads = grad_fn(params_tree, batch)
            grads = scaled_grads(grads, state["step"])
            g_bus = pin_bus(parambus.pack_tree(layout, grads))
            # COMPLETE: weighted combine of the landed payloads (decode
            # folded in when wire-coded), then the bus-resident EDM update
            # on the mixed iterate x(t) = W(t) φ̃(t).  Late slots
            # (straggler_plan) degrade to self-weight (DESIGN §8).
            late = (straggler_plan.late_at(g_step)
                    if straggler_plan is not None else None)
            x_mixed = complete(payloads, g_step, late=late)
            if codec is not None:
                sub = {"m": state["opt"]["m"], "psi": state["opt"]["psi"]}
                phi_new, new_opt = local_opt.step(x_mixed, g_bus, sub)
                new_opt = {**new_opt, "e": e_new}
            else:
                phi_new, new_opt = local_opt.step(x_mixed, g_bus,
                                                  state["opt"])
            metrics = {
                "loss": jnp.mean(losses),
                "consensus": bus_consensus(x_mixed),
                "grad_norm": bus_grad_norm(g_bus),
            }
            return {"params": x_mixed, "opt": new_opt,
                    "pipeline": parambus.pipeline_advance(pipe, phi_new),
                    "step": state["step"] + 1}, metrics

        return train_step

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params_tree = (parambus.unpack_tree(layout, state["params"])
                       if packed else state["params"])
        losses, grads = grad_fn(params_tree, batch)
        grads = scaled_grads(grads, state["step"])
        g_step = gossip_round_step(state["step"], run.gossip_every)
        g_in = pin_bus(parambus.pack_tree(layout, grads)) if packed else grads
        opt = opt_at(g_step)
        if run.gossip_every > 1:
            # local-EDM: amortize gossip over k steps.  lax.cond — not a
            # dual-evaluation jnp.where — so skip steps execute only the
            # identity-mixer update and never pay the gossip collectives
            # (the round clock `g_step` is replicated, so both branches
            # stay SPMD-consistent).
            local_opt = opt_at(g_step, mix_override=lambda t: t)
            do_gossip = (state["step"] % run.gossip_every) == run.gossip_every - 1
            new_params, new_opt = jax.lax.cond(
                do_gossip,
                lambda a: opt.step(*a),
                lambda a: local_opt.step(*a),
                (state["params"], g_in, state["opt"]))
        else:
            new_params, new_opt = opt.step(state["params"], g_in, state["opt"])
        if packed:
            # bus-path metrics: ONE fused reduction over each superbuffer
            # (pads are zero, so these equal the per-leaf reductions).
            consensus = bus_consensus(new_params)
            grad_norm = bus_grad_norm(g_in)
        else:
            consensus = consensus_distance(new_params)
            grad_norm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus,
            "grad_norm": grad_norm,
        }
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def init_state(model: Model, run: RunConfig, n_agents: int, key,
               shards: int = 1) -> TrainState:
    """All agents start from the same x(0) (paper's initialization).

    With the packed bus active the state is packed ONCE here (DESIGN §5):
    ``params`` is the ``(A, rows, 128)`` superbuffer and ``opt`` holds the
    bus-resident ``m``/``psi``; everything downstream stays in bus layout
    until checkpointing.  The overlapped pipeline (DESIGN §6) additionally
    carries ``pipeline`` — the double-buffered payload ``slot[2]`` with its
    parity bit, seeded with φ(0) = x(0) in the live slot (step 0 then
    reproduces the synchronous step exactly: W x(0) = x(0) at a replicated
    init).  ``shards`` must match the train step's FSDP shard count in
    shard-resident mode (DESIGN §7) so both sides build the same layout.
    """
    params1 = model.init(key)
    params = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (n_agents,) + l.shape), params1)
    feats = resolve_features(run)
    if feats.packed_bus:
        layout = bus_layout_for(model, n_agents, shards=shards,
                                groups=feats.groups)
        x_bus = parambus.pack_tree(layout, params)
        opt = make_edm_bus(run.alpha, run.beta, mix=lambda t: t,
                           block_rows=layout.block_rows)
        opt_state = opt.init(x_bus)
        if feats.wire != "f32":
            # bus-shaped EF residual (DESIGN §9), e(0) = 0: step 0 then
            # sends Q(φ(0)) exactly like the synchronous compressed step.
            opt_state["e"] = jnp.zeros_like(x_bus)
        state = {"params": x_bus, "opt": opt_state,
                 "step": jnp.zeros((), jnp.int32)}
        if feats.overlap:
            state["pipeline"] = parambus.make_pipeline(x_bus)
        return state
    mix = build_mixer(make_topology(run, n_agents), mode="static")
    opt = make_optimizer(run.algorithm, alpha=run.alpha, beta=run.beta, mix=mix)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------

def prepend_agent_axis(spec: P, agent_axis, fsdp_axis: Optional[str] = None) -> P:
    """(A, *shape) leaf spec: agent axis over `agent_axis`; optionally shard
    the first unsharded WEIGHT dim over `fsdp_axis` (agents="pod" mode).

    Stacked block leaves carry a leading layer-stack dim (spec entry 0 is
    None); FSDP must land on a weight dim, so skip entry 0 in that case —
    sharding the stack dim would be layer parallelism, and a 9-deep stack on
    a 16-way axis just gets sanitized away (weights silently replicated)."""
    entries = list(spec)
    if fsdp_axis is not None:
        start = 1 if (len(entries) > 1 and entries[0] is None) else 0
        for i in range(start, len(entries)):
            if entries[i] is None:
                entries[i] = fsdp_axis
                break
    return P(agent_axis, *entries)


def state_specs(model: Model, run: RunConfig, multi_pod: bool) -> Dict[str, Any]:
    """PartitionSpecs for the TrainState under the chosen agent granularity."""
    feats = resolve_features(run)
    if feats.packed_bus:
        if run.agents == "pod":
            # shard-resident bus (DESIGN §7): agent axis on 'pod', the
            # bus ROW axis FSDP-sharded over the pod-internal 'data' axis.
            agent_axis = "pod" if multi_pod else None
            spec = P(agent_axis, "data")
        else:
            # one (A, rows, 128) buffer per state slot, agent axis sharded
            # — rows/lane replicated (agents="data" has no FSDP axis free).
            agent_axis = ("pod", "data") if multi_pod else "data"
            spec = P(agent_axis)
        opt_specs = {"m": spec, "psi": spec}
        if feats.wire != "f32":
            opt_specs["e"] = spec   # bus-shaped residual shards like the bus
        specs = {"params": spec, "opt": opt_specs, "step": P()}
        if feats.overlap:
            # slot: (2, A, rows, 128) — the 2-slot dim replicated, then the
            # bus spec shifted right by one; parity is a replicated scalar.
            specs["pipeline"] = {"slot": P(None, *spec), "parity": P()}
        return specs

    base = model.param_specs()

    if run.agents == "data":
        agent_axis = ("pod", "data") if multi_pod else "data"
        fsdp = None
    elif run.agents == "pod":
        agent_axis = "pod" if multi_pod else None
        fsdp = "data"
    else:
        raise ValueError(run.agents)

    lift = lambda s: prepend_agent_axis(s, agent_axis, fsdp)
    pspecs = jax.tree.map(lift, base, is_leaf=lambda s: isinstance(s, P))

    opt_specs: Dict[str, Any] = {}
    # every optimizer state pytree mirrors the params tree
    n_slots = {"edm": ("m", "psi"), "edm_ef": ("m", "psi", "e"),
               "ed": ("m", "psi"), "dsgd": (),
               "dmsgd": ("m",), "dsgt": ("y", "g_prev"),
               "dsgt_hb": ("y", "g_prev", "m"), "decentlam": ("m",),
               "qg": ("m",)}[run.algorithm]
    for slot in n_slots:
        opt_specs[slot] = pspecs
    return {"params": pspecs, "opt": opt_specs, "step": P()}


def batch_spec_tree(model: Model, run: RunConfig, multi_pod: bool):
    """Specs for the (A, b, ...) training batch."""
    if run.agents == "data":
        agent_axis = ("pod", "data") if multi_pod else "data"
        inner = None
    else:
        agent_axis = "pod" if multi_pod else None
        inner = "data"
    cfg = model.cfg
    specs = {"tokens": P(agent_axis, inner, None)}
    if cfg.family in ("vlm", "encdec"):
        specs["frontend"] = P(agent_axis, inner, None, None)
    return specs
