"""Minimal dependency-free checkpointing: pytree ↔ .npz with path keys."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

__all__ = ["save", "load"]

_SEP = "|"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(path: str, tree: Any) -> None:
    arrays, _ = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (dtypes/shapes validated)."""
    data = np.load(path)
    arrays, treedef = _flatten(like)
    restored = {}
    for key, ref in arrays.items():
        got = data[key]
        assert got.shape == ref.shape, (key, got.shape, ref.shape)
        restored[key] = got
    leaves = [restored[k] for k in arrays.keys()]
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
