"""Minimal dependency-free checkpointing: pytree ↔ .npz with path keys.

Checkpoint format note (DESIGN §5): checkpoints always store the **logical**
parameter tree — per-leaf arrays under path keys — never the packed bus
buffer.  A bus-resident train state (``RunConfig.packed_bus``) is unpacked
on save and re-packed on load via the ``layout=`` argument, so checkpoints
are interchangeable between bus and tree-resident runs and survive layout
changes (block-row retuning, dtype-policy changes) across restarts.

The overlapped pipeline's state (DESIGN §6) follows the same rule:
:func:`save_state` normalizes the double-buffered ``pipeline`` to its LIVE
payload (``slot[parity]``, stored as a logical tree) plus the parity bit —
the dead slot is never serialized, and :func:`load_state` re-materializes a
``slot[2]`` whose live slot holds φ(t), so a resumed run reproduces the
pipeline trajectory exactly.

Policy groups (DESIGN §12) ride the same contract for free: a grouped
:class:`~repro.core.bus.BusLayout` permutes leaf *rows* inside the bus,
but the save path unpacks to the logical tree before anything touches
disk — so checkpoints written under one group spec load under any other
(1-group → 2-group, regrouped, or back to tree-resident), because
``layout=`` on each side is only that side's row map.  ``_is_bus`` keys
on the layout's total ``rows``, which includes every group's tail pad.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Sequence

import jax
import numpy as np

__all__ = ["save", "load", "save_state", "load_state", "resize_state",
           "load_state_resized", "export_consensus", "load_consensus"]

_SEP = "|"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _flatten_keys(tree: Any):
    """Path keys + leaves without materializing arrays (works on
    ShapeDtypeStructs — ``load`` only needs shapes, not values)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat]


def _is_bus(leaf: Any, layout) -> bool:
    """A leaf is a packed-bus buffer iff it is ``(..A.., rows, 128)``-shaped
    for this layout — anything else (step counters, parity bits) passes
    through the bus translation untouched."""
    from repro.core.bus import LANE
    shape = tuple(getattr(leaf, "shape", ()))
    return len(shape) == 3 and shape[-2:] == (layout.rows, LANE)


def _unbus(tree: Any, layout) -> Any:
    """Expand every (A, rows, 128) bus leaf of ``tree`` into its logical
    subtree (tree may be one bus buffer, or e.g. a {"m","psi"} dict of
    them); non-bus leaves (scalars like ``step``) pass through."""
    from repro.core.bus import unpack_tree
    return jax.tree.map(
        lambda b: unpack_tree(layout, b) if _is_bus(b, layout) else b, tree)


def save(path: str, tree: Any, layout: Optional[Any] = None) -> None:
    """Save ``tree`` as .npz.  ``layout`` marks ``tree``'s bus-shaped array
    leaves as packed-bus buffers (:class:`~repro.core.bus.BusLayout`): they
    are unpacked to the logical tree first, keeping the on-disk format
    layout-independent.

    FSDP-sharded buses (DESIGN §7) serialize like any other state: the
    bus translation runs where the data lives and the logical tree is
    pulled to host once — the on-disk format carries no trace of the
    run's sharding or shard-padded layout, so a checkpoint saved sharded
    loads into a gathered run (or a different shard count) and vice
    versa."""
    if layout is not None:
        tree = _unbus(tree, layout)
    tree = jax.device_get(tree)
    arrays, _ = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)


def load(path: str, like: Any, layout: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (dtypes/shapes validated).

    With ``layout=``, ``like``'s bus-shaped leaves are packed-bus buffers:
    the checkpoint (stored logical, see :func:`save`) is loaded against the
    unpacked structure and re-packed into bus layout on the way out;
    non-bus leaves load as-is.
    """
    if layout is not None:
        from repro.core.bus import pack_tree
        # structural template only — eval_shape, so no unpack is computed
        template = jax.eval_shape(lambda t: _unbus(t, layout), like)
        logical = load(path, template)
        return jax.tree.map(
            lambda b, sub: pack_tree(layout, sub) if _is_bus(b, layout)
            else sub,
            like, logical,
            is_leaf=lambda x: _is_bus(x, layout))
    data = np.load(path)
    keys, refs = _flatten_keys(like)
    leaves = []
    for key, ref in zip(keys, refs):
        got = data[key]
        assert got.shape == tuple(ref.shape), (key, got.shape, ref.shape)
        leaves.append(got)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


# ---------------------------------------------------------------------------
# full TrainState checkpoints (params + opt + step [+ overlap pipeline])
# ---------------------------------------------------------------------------

def save_state(path: str, state: Any, layout: Optional[Any] = None) -> None:
    """Checkpoint a full trainer ``state`` dict.  Bus-resident slots unpack
    to logical trees per the format note; the overlap ``pipeline`` is
    normalized to ``{"phi": live payload, "parity": bit}`` — the spare slot
    is dead by construction and never hits disk."""
    tree = dict(state)
    pipe = tree.pop("pipeline", None)
    if pipe is not None:
        parity = np.asarray(jax.device_get(pipe["parity"]))
        live = np.asarray(jax.device_get(pipe["slot"]))[int(parity)]
        tree["pipeline"] = {"phi": live, "parity": parity}
    save(path, tree, layout=layout)


def load_state(path: str, like: Any, layout: Optional[Any] = None) -> Any:
    """Restore a full trainer state into the structure of ``like`` (the
    freshly built state of the resuming run).  Pipeline checkpoints carry
    only the live payload: the restored ``slot[2]`` holds φ(t) in BOTH
    slots, so ``slot[parity]`` is correct for any stored parity and the
    first resumed step overwrites the spare exactly as the uninterrupted
    run would.

    Wire-format changes across a restart (DESIGN §9): a checkpoint saved
    by an f32-wire run carries no ``opt["e"]`` residual — resuming it
    under ``wire ∈ {bf16, int8}`` zero-fills the residual, which is the
    EF-correct cold start (e(0) = 0).  The reverse direction (compressed
    → f32) needs nothing: :func:`load` reads only the keys the new state
    asks for, so a stale residual in the file is simply ignored."""
    import jax.numpy as jnp

    like2 = dict(like)
    e_like = None
    opt_like = like2.get("opt")
    if isinstance(opt_like, dict) and "e" in opt_like:
        have = set(np.load(path).files)
        if not any(k.split(_SEP)[:2] == ["opt", "e"] for k in have):
            opt_like = dict(opt_like)
            e_like = opt_like.pop("e")
            like2["opt"] = opt_like
    pipe_like = like2.pop("pipeline", None)
    if pipe_like is not None:
        slot = pipe_like["slot"]
        like2["pipeline"] = {
            "phi": jax.ShapeDtypeStruct(tuple(slot.shape[1:]), slot.dtype),
            "parity": jax.ShapeDtypeStruct((), jnp.int32),
        }
    tree = load(path, like2, layout=layout)
    if pipe_like is not None:
        pp = tree.pop("pipeline")
        phi = jnp.asarray(pp["phi"])
        tree["pipeline"] = {"slot": jnp.stack([phi, phi]),
                            "parity": jnp.asarray(pp["parity"], jnp.int32)}
    if e_like is not None:
        tree["opt"] = dict(tree["opt"])
        tree["opt"]["e"] = jax.tree.map(
            lambda l: jnp.zeros(tuple(l.shape), l.dtype), e_like)
    return tree


# ---------------------------------------------------------------------------
# train → serve handoff: consensus export (DESIGN §10)
# ---------------------------------------------------------------------------

def export_consensus(src_path: str, dst_path: str) -> None:
    """Export the EDM consensus iterate from a training checkpoint: the
    per-leaf mean over the leading agent axis of every ``params`` leaf,
    written as a single-replica params tree (no agent axis, no opt state).

    Why the mean: the gossip matrix W is doubly stochastic, so the agent
    mean is invariant under mixing and is exactly the consensus target the
    bias-corrected update drives every agent toward (PAPER.md; Momentum
    Tracking, arXiv 2209.15505) — x̄ is *the* trained artifact serving
    should load.

    Why this is sharding-independent: :func:`save` always materializes the
    logical gathered tree — bus-resident, FSDP-sharded (``agents="pod"``)
    and tree-resident runs write byte-identical params leaves — so a
    consensus export from a pod run equals the export from the gathered
    run, and the serving side re-lays it out under whatever
    ``serve_param_specs`` mesh it runs on.

    The reduction runs in float64 and rounds once to the stored dtype, so
    the export is independent of the agent count's summation order."""
    data = np.load(src_path)
    prefix = "params" + _SEP
    out = {}
    for k in data.files:
        if not k.startswith(prefix):
            continue
        leaf = data[k]
        out[k[len(prefix):]] = (
            leaf.mean(axis=0, dtype=np.float64).astype(leaf.dtype))
    assert out, f"{src_path}: no params leaves to export"
    os.makedirs(os.path.dirname(dst_path) or ".", exist_ok=True)
    np.savez(dst_path, **out)


def load_consensus(path: str, like_params: Any) -> Any:
    """Load a consensus export into the structure of ``like_params`` (a
    single-replica params tree / eval_shape thereof)."""
    return load(path, like_params)


# ---------------------------------------------------------------------------
# elastic join/leave: cross-size state resize (DESIGN §8)
# ---------------------------------------------------------------------------

def resize_state(state: Any, survivors: Sequence[int],
                 n_agents: int) -> Any:
    """Re-shape a trainer state from its saved agent set onto ``n_agents``.

    ``survivors`` selects (in order) which saved agents carry over; their
    rows are taken verbatim, so a shrink — and the A→A identity resize —
    is bit-exact.  When ``n_agents > len(survivors)``, re-admitted agents
    are appended with the join policy that keeps the first resumed step
    exactly the synchronous one for them:

    * ``params`` (x):  the consensus mean over the surviving agents
      (bus zero-pads stay zero under the mean, so the packed layout
      contract is preserved);
    * ``opt["psi"]``:  the new agent's own x row — ψ := x makes the
      bias-corrected payload φ = ψ₂ + x − ψ collapse to ψ₂ at the next
      step, i.e. a joining agent re-enters as if at step 0;
    * every other opt slot (m, trackers, error feedback):  zeros;
    * the overlap ``pipeline`` slots:  the new x row in both buffers
      (φ(0) = x(0), the same seeding :func:`~repro.train.trainer.
      init_state` uses).

    Operates directly on whatever layout the state is in — packed
    ``(A, rows, 128)`` buses and logical per-leaf trees resize the same
    way, along axis 0 (axis 1 for the pipeline's ``slot``).
    """
    import jax.numpy as jnp

    surv = np.asarray(list(survivors), dtype=np.int64)
    m = len(surv)
    assert m <= n_agents, (m, n_agents)
    pad = n_agents - m

    def keep(l, axis=0):
        return jnp.take(jnp.asarray(l), jnp.asarray(surv), axis=axis)

    def grow(kept, fill, axis=0):
        if pad == 0:
            return kept
        reps = [1] * kept.ndim
        reps[axis] = pad
        return jnp.concatenate([kept, jnp.tile(fill, reps)], axis=axis)

    new_params = jax.tree.map(
        lambda l: grow(keep(l), keep(l).mean(axis=0, keepdims=True)),
        state["params"])
    new_opt = {}
    for slot, sub in state.get("opt", {}).items():
        if slot == "psi":
            new_opt[slot] = jax.tree.map(
                lambda l, x: jnp.concatenate([keep(l), x[m:]], axis=0)
                if pad else keep(l), sub, new_params)
        else:
            new_opt[slot] = jax.tree.map(
                lambda l: grow(keep(l),
                               jnp.zeros_like(keep(l)[:1])), sub)
    out = dict(state)
    out["params"] = new_params
    out["opt"] = new_opt
    pipe = state.get("pipeline")
    if pipe is not None:
        slot = jax.tree.map(
            lambda l, x: jnp.concatenate(
                [keep(l, axis=1),
                 jnp.broadcast_to(x[None, m:],
                                  (l.shape[0], pad) + x.shape[1:])],
                axis=1) if pad else keep(l, axis=1),
            pipe["slot"], new_params)
        out["pipeline"] = {"slot": slot, "parity": pipe["parity"]}
    return out


def load_state_resized(path: str, like: Any, layout: Optional[Any] = None,
                       survivors: Optional[Sequence[int]] = None) -> Any:
    """Restore a checkpoint saved at A agents into a run built at A′.

    The saved agent count is read off the checkpoint itself; the state is
    loaded against an A-shaped template (the :class:`~repro.core.bus.
    BusLayout` is agent-count-agnostic, so the SAME ``layout`` serves both
    sizes) and then re-shaped by :func:`resize_state`.  ``survivors``
    defaults to the first ``min(A, A′)`` agents; A′ == A with default
    survivors round-trips bit-identically through :func:`load_state`.
    """
    data = np.load(path)
    pkeys = [k for k in data.files if k.split(_SEP)[0] == "params"]
    assert pkeys, f"{path}: no params leaves in checkpoint"
    a_old = int(data[pkeys[0]].shape[0])

    def agent_leaves(sub, a):
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((a,) + tuple(l.shape[1:]),
                                           l.dtype), sub)

    a_new = jax.tree.leaves(like["params"])[0].shape[0]
    if a_old == a_new and survivors is None:
        return load_state(path, like, layout=layout)

    like_old = {}
    for k, v in like.items():
        if k == "pipeline":
            slot = v["slot"]
            like_old[k] = {
                "slot": jax.ShapeDtypeStruct(
                    (slot.shape[0], a_old) + tuple(slot.shape[2:]),
                    slot.dtype),
                "parity": v["parity"]}
        elif k in ("params", "opt"):
            like_old[k] = agent_leaves(v, a_old)
        else:
            like_old[k] = v
    state_old = load_state(path, like_old, layout=layout)
    surv = (list(survivors) if survivors is not None
            else list(range(min(a_old, a_new))))
    return resize_state(state_old, surv, a_new)
