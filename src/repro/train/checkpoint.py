"""Minimal dependency-free checkpointing: pytree ↔ .npz with path keys.

Checkpoint format note (DESIGN §5): checkpoints always store the **logical**
parameter tree — per-leaf arrays under path keys — never the packed bus
buffer.  A bus-resident train state (``RunConfig.packed_bus``) is unpacked
on save and re-packed on load via the ``layout=`` argument, so checkpoints
are interchangeable between bus and tree-resident runs and survive layout
changes (block-row retuning, dtype-policy changes) across restarts.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "load"]

_SEP = "|"


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _flatten_keys(tree: Any):
    """Path keys + leaves without materializing arrays (works on
    ShapeDtypeStructs — ``load`` only needs shapes, not values)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat]


def _unbus(tree: Any, layout) -> Any:
    """Expand every (A, rows, 128) bus leaf of ``tree`` into its logical
    subtree (tree may be one bus buffer, or e.g. a {"m","psi"} dict of them)."""
    from repro.core.bus import unpack_tree
    return jax.tree.map(lambda b: unpack_tree(layout, b), tree)


def save(path: str, tree: Any, layout: Optional[Any] = None) -> None:
    """Save ``tree`` as .npz.  ``layout`` marks ``tree``'s array leaves as
    packed-bus buffers (:class:`~repro.core.bus.BusLayout`): they are
    unpacked to the logical tree first, keeping the on-disk format
    layout-independent."""
    if layout is not None:
        tree = _unbus(tree, layout)
    arrays, _ = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)


def load(path: str, like: Any, layout: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (dtypes/shapes validated).

    With ``layout=``, ``like``'s leaves are packed-bus buffers: the
    checkpoint (stored logical, see :func:`save`) is loaded against the
    unpacked structure and re-packed into bus layout on the way out.
    """
    if layout is not None:
        from repro.core.bus import pack_tree
        # structural template only — eval_shape, so no unpack is computed
        template = jax.eval_shape(lambda t: _unbus(t, layout), like)
        logical = load(path, template)
        return jax.tree.map(
            lambda b, sub: pack_tree(layout, sub), like, logical,
            is_leaf=lambda x: hasattr(x, "ndim") and getattr(x, "ndim", 0) == 3)
    data = np.load(path)
    keys, refs = _flatten_keys(like)
    leaves = []
    for key, ref in zip(keys, refs):
        got = data[key]
        assert got.shape == tuple(ref.shape), (key, got.shape, ref.shape)
        leaves.append(got)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
