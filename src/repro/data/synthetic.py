"""Synthetic data pipelines.

* :class:`SyntheticLM` — heterogeneous token streams for decentralized LM
  training: a shared order-1 Markov backbone (learnable structure) plus a
  per-agent Dirichlet-tilted unigram mixture controlling heterogeneity
  (the LM analogue of the paper's Dirichlet-φ CIFAR split).
* :func:`dirichlet_partition` — the paper's §E.3 label-skew partitioner.
* quadratic / logistic generators for the paper's §E.1/§E.2 benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "dirichlet_partition", "quadratic_problem",
           "logistic_problem"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    n_agents: int
    phi: float = 1.0          # Dirichlet concentration; smaller = more hetero
    mix: float = 0.5          # weight of the agent-specific unigram tilt
    sharpness: float = 4.0    # Markov logit scale: higher = lower entropy
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = min(self.vocab_size, 256)  # active head of the vocab
        self._V = V
        # shared Markov structure: each token prefers a few successors
        self._trans_logits = jnp.asarray(
            rng.normal(size=(V, V)).astype(np.float32) * self.sharpness)
        # per-agent unigram tilt ~ Dirichlet(phi)
        tilt = rng.dirichlet(np.full(V, self.phi), size=self.n_agents)
        self._tilt_logits = jnp.asarray(np.log(tilt + 1e-8).astype(np.float32))

    def sample(self, key, per_agent_batch: int) -> Dict[str, jax.Array]:
        """Returns {"tokens": (A, b, S) int32}."""
        A, b, S, V = self.n_agents, per_agent_batch, self.seq_len, self._V

        def agent_stream(key, tilt):
            def step(tok, key):
                logits = self._trans_logits[tok] * (1 - self.mix) \
                    + tilt[None] * self.mix
                nxt = jax.random.categorical(key, logits, axis=-1)
                return nxt, nxt
            k0, k1 = jax.random.split(key)
            tok0 = jax.random.randint(k0, (b,), 0, V)
            _, toks = jax.lax.scan(step, tok0, jax.random.split(k1, S - 1))
            return jnp.concatenate([tok0[None], toks], 0).T  # (b, S)

        keys = jax.random.split(key, A)
        tokens = jax.vmap(agent_stream)(keys, self._tilt_logits)
        return {"tokens": tokens.astype(jnp.int32)}


def dirichlet_partition(labels: np.ndarray, n_agents: int, phi: float,
                        seed: int = 0) -> list:
    """Paper §E.3: allocate p_ki ~ Dir(φ) fraction of class-k samples to
    agent i.  Returns a list of index arrays (one per agent)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    per_agent: list = [[] for _ in range(n_agents)]
    for k in classes:
        idx = np.where(labels == k)[0]
        rng.shuffle(idx)
        p = rng.dirichlet(np.full(n_agents, phi))
        cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            per_agent[i].append(part)
    return [np.concatenate(parts) for parts in per_agent]


def quadratic_problem(n: int, d: int = 10, p: int = 20, c: float = 1.0,
                      sigma: float = 0.05, seed: int = 0):
    """Paper §E.1 linear-regression setup.

    f_i(x) = ½ E‖y_i − A_i x‖²,  heterogeneity controlled by c
    (x_i* = x* + (u_i − x*)/c; larger c → less heterogeneity).

    Returns (grad_fn(x, key) stochastic, full_grad_fn(x), x_opt, zeta2).
    """
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, p, d)).astype(np.float32)
    u = rng.normal(size=(n, d)).astype(np.float32)
    AtA = np.einsum("npd,npe->nde", A, A)
    x_star = np.linalg.solve(AtA.sum(0), np.einsum("nde,ne->d", AtA, u))
    x_i = x_star[None] + (u - x_star[None]) / c
    b = np.einsum("npd,nd->np", A, x_i)
    Aj, bj = jnp.asarray(A), jnp.asarray(b)

    def full_grad(x):  # x: (n, d)
        r = jnp.einsum("npd,nd->np", Aj, x) - bj
        return jnp.einsum("npd,np->nd", Aj, r) / p

    def stoch_grad(x, key):
        noise = sigma * jax.random.normal(key, x.shape)
        return full_grad(x) + noise

    g_at_opt = np.einsum(
        "npd,np->nd", A, np.einsum("npd,d->np", A, x_star) - b) / p
    zeta2 = float(np.mean(np.sum(g_at_opt ** 2, -1)))
    return stoch_grad, full_grad, jnp.asarray(x_star), zeta2


def logistic_problem(n: int, d: int = 20, m: int = 2000, sigma_h: float = 1.0,
                     mu: float = 0.01, sigma_s: float = 0.1, seed: int = 0):
    """Paper §E.2: ℓ₂-regularized logistic regression, heterogeneity via
    x_i = x₀ + ε_i, ε ~ N(0, σ_h² I).  Full-batch grads + additive noise.

    Returns (stoch_grad(x, key), full_grad(x), mean_loss(x_mean))."""
    rng = np.random.default_rng(seed)
    x0 = np.ones(d, np.float32)
    xi = x0[None] + sigma_h * rng.normal(size=(n, d)).astype(np.float32)
    U = rng.normal(size=(n, m, d)).astype(np.float32)
    z = rng.uniform(size=(n, m)).astype(np.float32)
    pv = 1.0 / (1.0 + np.exp(-np.einsum("nmd,nd->nm", U, xi)))
    v = np.where(z <= pv, 1.0, -1.0).astype(np.float32)
    Uj, vj = jnp.asarray(U), jnp.asarray(v)

    def full_grad(x):  # (n, d)
        margins = jnp.einsum("nmd,nd->nm", Uj, x) * vj
        coef = -vj * jax.nn.sigmoid(-margins)      # dℓ/dz
        return jnp.einsum("nmd,nm->nd", Uj, coef) / m + mu * x

    def stoch_grad(x, key):
        return full_grad(x) + sigma_s * jax.random.normal(key, x.shape)

    def mean_loss(x):  # scalar loss of the averaged model over all agents
        margins = jnp.einsum("nmd,d->nm", Uj, x) * vj
        return jnp.mean(jnp.log1p(jnp.exp(-margins))) + 0.5 * mu * jnp.sum(x * x)

    return stoch_grad, full_grad, mean_loss
