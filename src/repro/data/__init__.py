"""repro.data — synthetic pipelines + the paper's experiment generators."""
from .synthetic import (  # noqa: F401
    SyntheticLM, dirichlet_partition, logistic_problem, quadratic_problem,
)
