"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6, MHA.
[arXiv:2401.06066]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400, rope_theta=1e4,
    n_experts=64, experts_per_token=6, n_shared_experts=2,
    source="arXiv:2401.06066",
)
