"""Model / run configuration system.

``ModelConfig`` is a frozen dataclass describing any of the six architecture
families; ``layer_kinds`` derives the per-layer (mixer, ffn) pattern used by
the period-block scan in :mod:`repro.models.transformer`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

__all__ = ["ModelConfig", "RunConfig", "layer_kinds", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0                # 0 → d_model // n_heads
    # attention options
    pos_emb: str = "rope"            # rope | sinusoidal (encdec)
    rope_theta: float = 1e4
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_gated: bool = True           # SwiGLU vs plain GELU MLP
    sliding_window: int = 0          # 0 = full causal attention
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1               # layer i uses MoE FFN iff i % moe_every == moe_offset
    moe_offset: int = 0
    dense_d_ff: int = 0              # ffn width of non-MoE layers in mixed models
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 → ceil(d_model / 16)
    # hybrid: layer i is attention iff i % attn_every == attn_offset (else SSM)
    attn_every: int = 0              # 0 → all attention (or all-SSM for family=ssm)
    attn_offset: int = 0
    # encoder-decoder (audio)
    n_enc_layers: int = 0
    # modality frontend stub: number of precomputed embedding tokens supplied
    n_frontend_tokens: int = 0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # citation / provenance (model card or paper)
    source: str = ""

    # ---- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    @property
    def is_decoder_lm(self) -> bool:
        return self.family in ("dense", "moe", "ssm", "hybrid", "vlm")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, V = self.d_model, self.vocab_size
        total = V * d * 2  # embed + untied lm head
        for mixer, ffn in layer_kinds(self):
            if mixer == "attn" or mixer == "xattn":
                qk = d * self.n_heads * self.hd + d * self.n_kv_heads * self.hd * 2
                total += qk + self.n_heads * self.hd * d + 2 * d
                if mixer == "xattn":
                    total += qk + self.n_heads * self.hd * d + d
            elif mixer == "ssm":
                di, s, r = self.d_inner, self.ssm_state, self.dt_rank
                total += d * 2 * di + self.ssm_conv * di + di * (r + 2 * s)
                total += r * di + di * s + di + di * d + d
            if ffn == "dense":
                ff = self.dense_d_ff or self.d_ff
                total += d * ff * (3 if self.mlp_gated else 2) + d
            elif ffn == "moe":
                e_ff = self.d_ff
                total += d * self.n_experts + self.n_experts * d * e_ff * 3 + d
                if self.n_shared_experts:
                    total += d * e_ff * self.n_shared_experts * 3
        if self.family == "encdec":
            # encoder layers (self-attn + dense ffn)
            enc = self.n_enc_layers * (
                d * self.n_heads * self.hd * 2 + d * self.n_kv_heads * self.hd * 2
                + (self.d_ff * d * (3 if self.mlp_gated else 2)) + 3 * d)
            total += enc
        return total

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        total = self.n_params()
        for mixer, ffn in layer_kinds(self):
            if ffn == "moe":
                inactive = (self.n_experts - self.experts_per_token) * d * self.d_ff * 3
                total -= inactive
        return total


def layer_kinds(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """Per-layer (mixer, ffn) for the decoder stack.

    mixer ∈ {attn, ssm};  ffn ∈ {dense, moe, none}.
    """
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            mixer = "ssm"
        elif cfg.family == "hybrid" and cfg.attn_every:
            mixer = "attn" if i % cfg.attn_every == cfg.attn_offset else "ssm"
        else:
            mixer = "attn"
        if cfg.n_experts and i % cfg.moe_every == cfg.moe_offset:
            ffn = "moe"
        elif cfg.family == "ssm":
            ffn = "none"       # mamba-1 blocks have no separate FFN
        else:
            ffn = "dense"
        kinds.append((mixer, ffn))
    return kinds


def block_period(cfg: ModelConfig) -> int:
    """Smallest p such that layer kinds repeat with period p and p | n_layers."""
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(kinds[i] == kinds[i % p] for i in range(n)):
            return p
    return n


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training / serving run parameters (input shape + distribution)."""
    global_batch: int = 256
    seq_len: int = 4096
    mode: str = "train"              # train | prefill | decode
    # decentralized training
    algorithm: str = "edm"
    alpha: float = 1e-3
    beta: float = 0.9
    topology: str = "ring"           # ring | exp | torus | full | hier
    agents: str = "data"             # data | pod  (DESIGN §3)
    gossip_engine: str = "shifts"    # dense | shifts | ppermute  (DESIGN §3)
    # time-varying gossip (DESIGN §4): static wraps `topology`; round_robin =
    # one-peer exp rounds; alt_hier = intra-pod rounds + one inter-pod round
    gossip_schedule: str = "static"  # static | round_robin | alt_hier
    gossip_period: int = 0           # alt_hier: intra rounds per inter (0→1)
    gossip_seed: int = 0             # round_robin: offset-order shuffle (0=off)
    agents_per_device: int = 1       # blocked ppermute: A > device count (§4)
    # packed parameter bus (DESIGN §5): params + EDM state live in one
    # (A, rows, 128) superbuffer — one edm_update pallas_call and one
    # ppermute per gossip term per step.  None = auto: on for the
    # algorithm="edm" + gossip_engine="ppermute" production path.
    packed_bus: Optional[bool] = None
    # overlapped gossip pipeline (DESIGN §6): "off" = synchronous gossip on
    # the critical path (bit-identical to the plain bus step); "delayed" =
    # one-step-stale mixing — the live payload's permutes are issued before
    # the backward pass and combined after it, so wire time hides behind
    # compute.  Requires the packed bus (the payload is ONE buffer).
    overlap: str = "off"             # off | delayed
    gossip_dtype: str = "float32"    # bf16 payload is a §Perf lever
    # quantized gossip wire (DESIGN §9): wire format of the bus permutes.
    # "bf16" / "int8" route the packed-bus step through the error-feedback
    # codec (bus-shaped residual in the opt state, decode folded into the
    # combine); "f32" is the byte-identical legacy wire.  Packed bus only;
    # mutually exclusive with gossip_dtype != float32 (the codec replaces
    # that cast lever and, unlike it, composes with overlap="delayed").
    wire: str = "f32"                # f32 | bf16 | int8
    gossip_every: int = 1            # gossip every k steps (local-EDM, §Perf)
    # policy groups (DESIGN §12): the single declarative entry point for
    # WHAT gossips, HOW OFTEN and at WHAT precision.  "" = one default
    # "dense" group (bit-identical to the ungrouped bus); presets
    # "moe[:k]" / "ssm[:k]" put expert / conv+SSM-state leaves in their
    # own group (k = that group's gossip_every, 0 = full opt-out); a JSON
    # list gives explicit specs: [{"name": ..., "match": [...],
    # "gossip_every": ..., "wire": ..., "schedule": ...}, ...].
    # Parsed by repro.train.trainer.resolve_group_specs.
    gossip_groups: str = ""
    moe_sharding: bool = False       # explicit MoE dispatch constraints (§Perf)
    moe_impl: str = "gspmd"          # gspmd | shard_map  (§Perf serving path)
    attn_bf16_path: bool = False     # bf16 attention data path (§Perf)
    remat: bool = True
    remat_policy: str = "full"       # full | dots  (§Perf)
    seq_parallel: bool = False       # sequence-sharded residual (§Perf)
    warmup_steps: int = 0            # LR schedule (0 = constant α)
    total_steps: int = 0
    # serving
    decode_window: int = 0           # 0 → full KV cache; else sliding window


# the four assigned input shapes ------------------------------------------------
INPUT_SHAPES = {
    "train_4k":    RunConfig(global_batch=256, seq_len=4096,   mode="train"),
    "prefill_32k": RunConfig(global_batch=32,  seq_len=32768,  mode="prefill"),
    "decode_32k":  RunConfig(global_batch=128, seq_len=32768,  mode="decode"),
    "long_500k":   RunConfig(global_batch=1,   seq_len=524288, mode="decode",
                             decode_window=8192),
}


def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (≤4 experts etc.)."""
    period = block_period(cfg)
    n_layers = max(n_layers, period)
    n_layers = (n_layers + period - 1) // period * period
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads else 0
    if n_kv and cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads  # keep MHA archs MHA
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=min(cfg.d_model, d_model),
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=64 if cfg.n_heads else 0,
        d_ff=min(cfg.d_ff, 2 * d_model) if cfg.d_ff else 0,
        dense_d_ff=min(cfg.dense_d_ff, 2 * d_model) if cfg.dense_d_ff else 0,
        vocab_size=min(cfg.vocab_size, vocab),
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        # dropless at smoke scale (C ≥ T·k/E · E/k): prefill↔decode must agree
        capacity_factor=8.0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_dt_rank=8 if cfg.ssm_state else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_frontend_tokens=min(cfg.n_frontend_tokens, 16),
        attn_every=min(cfg.attn_every, n_layers) if cfg.attn_every else 0,
        attn_offset=min(cfg.attn_offset, min(cfg.attn_every, n_layers) - 1)
        if cfg.attn_every else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        dtype="float32",
    )
    return dataclasses.replace(cfg, **updates)
