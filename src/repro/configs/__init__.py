"""Architecture registry: the 10 assigned configs + the paper's own setups.

Every entry cites its source model card / paper.  ``get_config(name)`` returns
the full-size config; ``get_smoke_config(name)`` the reduced same-family
variant used by CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (  # noqa: F401
    INPUT_SHAPES, ModelConfig, RunConfig, block_period, layer_kinds, reduced,
)

ARCH_IDS: List[str] = [
    "pixtral_12b",
    "qwen3_moe_235b_a22b",
    "falcon_mamba_7b",
    "qwen1_5_110b",
    "whisper_small",
    "smollm_360m",
    "starcoder2_7b",
    "jamba_1_5_large_398b",
    "deepseek_moe_16b",
    "qwen3_14b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
# hyphenated ids as assigned
_ALIASES.update({
    "pixtral-12b": "pixtral_12b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "whisper-small": "whisper_small",
    "smollm-360m": "smollm_360m",
    "starcoder2-7b": "starcoder2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-14b": "qwen3_14b",
})


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
