"""StarCoder2-7B — dense GQA + RoPE, non-gated GELU MLP. [arXiv:2402.19173]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152, rope_theta=1e5, mlp_gated=False,
    qkv_bias=True,
    source="arXiv:2402.19173",
)
