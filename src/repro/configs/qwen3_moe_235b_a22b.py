"""Qwen3-MoE-235B-A22B — 128 experts, top-8, GQA kv=4, qk-norm.
[hf:Qwen/Qwen3-30B-A3B family, scaled per assignment]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, rope_theta=1e6, qk_norm=True,
    n_experts=128, experts_per_token=8,
    source="hf:Qwen/Qwen3-30B-A3B",
)
