"""Pixtral-12B — VLM: Pixtral ViT frontend (stub) + Mistral-Nemo-style LM.
[hf:mistralai/Pixtral-12B-2409]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, rope_theta=1e9,
    n_frontend_tokens=256,   # ViT patch embeddings supplied by the stub
    source="hf:mistralai/Pixtral-12B-2409",
)
