"""Whisper-small — enc-dec audio; conv/mel frontend is a stub supplying
1500 frame embeddings. [arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, mlp_gated=False, pos_emb="sinusoidal",
    n_frontend_tokens=1500,
    source="arXiv:2212.04356",
)
