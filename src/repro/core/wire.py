"""Quantized gossip wire: bf16 / int8 per-block-scaled bus payloads.

Every gossip permute of the packed bus (DESIGN §5) ships an ``(A, rows,
128)`` f32 superbuffer — 4 bytes/elem on the single hottest communication
path.  This module is the wire codec layer (DESIGN §9): a
:class:`WireCodec` encodes the bus payload into one of three wire formats
before the collective-permutes and decodes it inside the combine, so the
bytes that actually cross ICI/DCI shrink while every iterate, accumulator
and combine stays f32.

Wire formats (``WIRE_FORMATS``):

* ``f32``  — identity; the pre-§9 wire, byte-exact legacy path.
* ``bf16`` — round-to-nearest bf16 payload; 2 bytes/elem (2× cut).
* ``int8`` — symmetric per-block int8 with one f32 scale per
  ``(block_rows, 128)`` bus block; 1 byte/elem + 4/(block_rows·128)
  scale overhead (≈4× cut).  The scale blocks ARE the fused kernels' grid
  tiles, and :class:`~repro.core.bus.BusLayout` rounds ``rows`` to a
  multiple of ``block_rows * shards`` — so every FSDP shard's row block
  holds whole scale blocks and encodes/decodes **shard-locally** (the
  ``agents="pod"`` composition of DESIGN §7 never crosses a shard
  boundary for a scale).

int8 block math (the reference the Pallas kernels mirror)::

    absmax = max(|x|) over the (block_rows, 128) block (non-finite → 0)
    scale  = absmax / 127
    q      = clip(round(x * 127 / absmax), -127, 127)   int8
    deq    = q * scale

Guards: an all-zero block (the bus pad tail!) yields ``absmax == 0`` →
``scale == 0`` and ``q == 0`` — no 0/0 NaN, and pads decode to EXACT zero,
preserving the bus pad-zero contract the metrics rely on.  Non-finite
inputs cannot poison a block: ±Inf saturates to ±127·scale of the finite
absmax and NaN encodes to 0 (deterministic, never a garbage scale).

Error feedback (DESIGN §9): EDM's bias-corrected payload φ = ψ' + x − ψ is
a small difference of large iterates; quantizing it naively injects a
*persistent* bias amplified by (1−λ)⁻¹ (the per-leaf ``edm_ef`` docstring
measured ~200× floor inflation).  The bus-resident EF step therefore sends
``Q(φ + e)`` and carries the residual ``e`` (see
:func:`repro.core.optimizers.make_edm_bus_ef` and :func:`encode_ef`).

Residual semantics under time-varying schedules — the §9 decision:
**cross-round carry**.  The residual is *sender-local* (one bus-shaped
buffer per agent, not per edge): every round encodes the full ``φ + e``
once and ships the same payload to all of that round's targets — including
the agent itself through its self term, so every receiver mixes the same
quantized value and W φ̃ stays consensus-consistent.  A round that skips a
peer (``RoundRobinExp`` rotating offsets, ``ElasticSchedule`` masked
rounds) cannot orphan the residual: ``e`` is re-added to the *next*
round's payload and each round's W is doubly stochastic, so the
correction reaches every peer through the period product.  Dead agents
under a liveness mask keep quantizing their weight-1 self term, and EF
cancels the self-quantization drift the naive wire would accumulate.
A per-round residual (reset e := 0 each round) would be naive
quantization with extra steps — rejected.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = ["WIRE_FORMATS", "WireCodec", "make_codec", "encode_ef"]

WIRE_FORMATS = ("f32", "bf16", "int8")


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Encode/decode one wire format for ``(..., rows, 128)`` f32 buses.

    Hashable (frozen, scalar fields) so it can key jit static args.  The
    encoded *payload* is the pytree the mixing engines permute leaf-wise:

    * ``f32``  — the input array, untouched;
    * ``bf16`` — one bf16 array of the input shape;
    * ``int8`` — ``(q, scale)``: int8 data of the input shape + f32 scales
      of shape ``(*batch, rows // block_rows)`` (one per grid tile, in
      tile order — permuting both arrays with the same agent-axis plan
      keeps every block next to its scale).
    """

    fmt: str
    block_rows: int

    def __post_init__(self):
        assert self.fmt in WIRE_FORMATS, self.fmt
        assert self.block_rows > 0 and self.block_rows % 8 == 0, \
            self.block_rows

    # ---- wire facts ------------------------------------------------------
    @property
    def wire_dtype(self):
        return {"f32": jnp.float32, "bf16": jnp.bfloat16,
                "int8": jnp.int8}[self.fmt]

    def payload_bytes(self, n_elems: int) -> int:
        """Modeled wire bytes for an ``n_elems``-element payload (data +
        int8 per-block scale sidecar) — the number
        :func:`repro.core.schedule.wire_bytes_per_step` multiplies rows
        by, replacing the pre-§9 hardcoded 4 bytes/elem."""
        from repro.core.bus import LANE
        if self.fmt == "f32":
            return 4 * n_elems
        if self.fmt == "bf16":
            return 2 * n_elems
        n_blocks = math.ceil(n_elems / (self.block_rows * LANE))
        return n_elems + 4 * n_blocks

    def compression_ratio(self, n_elems: int) -> float:
        """f32 bytes / this format's bytes for the same payload."""
        return 4.0 * n_elems / self.payload_bytes(n_elems)

    # ---- codec -----------------------------------------------------------
    def _blocked(self, x):
        *batch, rows, lane = x.shape
        assert rows % self.block_rows == 0, (x.shape, self.block_rows)
        nb = rows // self.block_rows
        return x.reshape(*batch, nb, self.block_rows * lane), nb

    def encode(self, x):
        """f32 ``(..., rows, 128)`` bus → wire payload (pure jnp; the
        fused path is ``repro.kernels.ops.edm_update_bus_ef``)."""
        if self.fmt == "f32":
            return x
        if self.fmt == "bf16":
            return x.astype(jnp.bfloat16)
        blocks, nb = self._blocked(x)
        mag = jnp.where(jnp.isfinite(blocks), jnp.abs(blocks), 0.0)
        absmax = jnp.max(mag, axis=-1)
        scale = absmax / 127.0
        inv = jnp.where(absmax > 0.0, 127.0 / jnp.maximum(absmax, 1e-30),
                        0.0)
        q = jnp.clip(jnp.round(blocks * inv[..., None]), -127.0, 127.0)
        q = jnp.where(jnp.isnan(blocks), 0.0, q)     # NaN → 0, ±Inf → ±127
        return (q.astype(jnp.int8).reshape(x.shape), scale)

    def decode(self, payload):
        """Wire payload → f32 bus."""
        if self.fmt == "f32":
            return payload
        if self.fmt == "bf16":
            return payload.astype(jnp.float32)
        q, scale = payload
        blocks, nb = self._blocked(q.astype(jnp.float32))
        return (blocks * scale[..., None]).reshape(q.shape)

    def quantize(self, x):
        """The quantization operator Q = decode ∘ encode (the reference
        oracle: permutes commute with decode, so the wire-coded engines
        must equal the f32 engines applied to ``quantize(x)`` exactly)."""
        return self.decode(self.encode(x))

    # ---- payload-as-pytree helpers --------------------------------------
    def payload_leaves(self, payload):
        """The payload's arrays in canonical order (data first)."""
        return payload if self.fmt == "int8" else (payload,)

    def payload_from_leaves(self, leaves):
        leaves = tuple(leaves)
        return leaves if self.fmt == "int8" else leaves[0]

    def map_payload(self, fn, payload):
        """Apply an array op (a permute) to every payload component."""
        return self.payload_from_leaves(
            fn(l) for l in self.payload_leaves(payload))


def make_codec(fmt: str, block_rows: int) -> WireCodec:
    """Wire codec for ``fmt`` ∈ WIRE_FORMATS with the bus layout's
    ``block_rows`` as the int8 scale-block height (= the fused kernels'
    grid tile, so scales and tiles are the same partition)."""
    return WireCodec(fmt=fmt, block_rows=block_rows)


def encode_ef(codec: WireCodec, c):
    """Error-feedback encode: ``(payload, residual)`` for the corrected
    payload ``c = φ + e`` — the jnp reference of the fused
    quantize+residual pass (``edm_update_bus_ef``), and the overlap
    pipeline's issue-time encode (DESIGN §9: quantize at issue time,
    residual accounted at complete time)."""
    payload = codec.encode(c)
    if codec.fmt == "f32":
        return payload, jnp.zeros_like(c)
    return payload, c - codec.decode(payload)
