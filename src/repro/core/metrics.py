"""Diagnostics used throughout the paper's analysis and our experiments."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "agent_mean",
    "bus_consensus",
    "bus_grad_norm",
    "consensus_distance",
    "grad_norm_at_mean",
    "heterogeneity_zeta2",
    "tree_sqnorm",
]


def tree_sqnorm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)


def agent_mean(tree: Any) -> Any:
    """x̄ = (1/n) Σ_i x_i  over the leading agent axis."""
    return jax.tree.map(lambda l: jnp.mean(l, axis=0, keepdims=True), tree)


def consensus_distance(tree: Any) -> jax.Array:
    """‖X − X̄‖²_F — the paper's deviation term E‖P_I X‖²."""
    mean = agent_mean(tree)
    return tree_sqnorm(jax.tree.map(lambda x, m: x - m, tree, mean))


# ---------------------------------------------------------------------------
# packed-bus diagnostics (DESIGN §5/§6): the bus's pad elements are zero by
# layout contract, so a single fused reduction over the (A, rows, 128)
# superbuffer equals the per-leaf reduction over the logical tree — no
# unpack, no per-leaf reduction kernels on the metrics path.
# ---------------------------------------------------------------------------

def bus_consensus(bus: jax.Array) -> jax.Array:
    """‖X − X̄‖²_F over a packed ``(A, rows, 128)`` bus in ONE reduction
    (pad rows deviate by 0, so this equals the logical-tree consensus)."""
    dev = bus - jnp.mean(bus, axis=0, keepdims=True)
    return jnp.sum(jnp.square(dev.astype(jnp.float32)))


def bus_grad_norm(g_bus: jax.Array) -> jax.Array:
    """Global gradient norm over a packed gradient bus in ONE reduction
    (equals the per-leaf sqrt-of-sum over the unpacked grads: the bus is
    f32 and its pads are zero)."""
    return jnp.sqrt(jnp.sum(jnp.square(g_bus.astype(jnp.float32))))


def grad_norm_at_mean(grad_fn, params: Any) -> jax.Array:
    """‖∇f(x̄)‖² where grad_fn maps a single-agent pytree to its gradient."""
    mean = jax.tree.map(lambda l: jnp.mean(l, axis=0), params)
    return tree_sqnorm(grad_fn(mean))


def heterogeneity_zeta2(per_agent_grads: Any) -> jax.Array:
    """ζ² = (1/n) Σ_i ‖∇f_i − ∇f‖²  evaluated at a common point
    (per_agent_grads leaves: (A, ...))."""
    mean = agent_mean(per_agent_grads)
    dev = jax.tree.map(lambda g, m: g - m, per_agent_grads, mean)
    n = jax.tree.leaves(per_agent_grads)[0].shape[0]
    return consensus_distance_from_dev(dev) / n


def consensus_distance_from_dev(dev: Any) -> jax.Array:
    return tree_sqnorm(dev)
