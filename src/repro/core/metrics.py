"""Diagnostics used throughout the paper's analysis and our experiments."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "agent_mean",
    "consensus_distance",
    "grad_norm_at_mean",
    "heterogeneity_zeta2",
    "tree_sqnorm",
]


def tree_sqnorm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)


def agent_mean(tree: Any) -> Any:
    """x̄ = (1/n) Σ_i x_i  over the leading agent axis."""
    return jax.tree.map(lambda l: jnp.mean(l, axis=0, keepdims=True), tree)


def consensus_distance(tree: Any) -> jax.Array:
    """‖X − X̄‖²_F — the paper's deviation term E‖P_I X‖²."""
    mean = agent_mean(tree)
    return tree_sqnorm(jax.tree.map(lambda x, m: x - m, tree, mean))


def grad_norm_at_mean(grad_fn, params: Any) -> jax.Array:
    """‖∇f(x̄)‖² where grad_fn maps a single-agent pytree to its gradient."""
    mean = jax.tree.map(lambda l: jnp.mean(l, axis=0), params)
    return tree_sqnorm(grad_fn(mean))


def heterogeneity_zeta2(per_agent_grads: Any) -> jax.Array:
    """ζ² = (1/n) Σ_i ‖∇f_i − ∇f‖²  evaluated at a common point
    (per_agent_grads leaves: (A, ...))."""
    mean = agent_mean(per_agent_grads)
    dev = jax.tree.map(lambda g, m: g - m, per_agent_grads, mean)
    n = jax.tree.leaves(per_agent_grads)[0].shape[0]
    return consensus_distance_from_dev(dev) / n


def consensus_distance_from_dev(dev: Any) -> jax.Array:
    return tree_sqnorm(dev)
