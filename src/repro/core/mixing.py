"""Mixing engines: apply W to a pytree with a leading agent axis.

Three interchangeable engines (tests assert they agree to float tolerance):

* :func:`mix_dense`    — explicit ``einsum('ij,j...->i...', W, x)``.  Used for
  paper-scale simulation and as the oracle.
* :func:`mix_shifts`   — weighted sum of ``jnp.roll`` terms.  On a sharded
  agent axis XLA lowers every roll to a ``collective-permute``, but the
  schedule is GSPMD's to choose.
* :func:`mix_ppermute` — the production gossip path (DESIGN §3):
  ``shard_map`` + one explicit ``jax.lax.ppermute`` per gossip term, with the
  weighted accumulation optionally fused into a single n-ary Pallas combine
  (:func:`repro.kernels.ops.gossip_axpy`).  Hierarchical topologies decompose
  per term onto the matching mesh sub-axis, so intra-pod permutes never leave
  the pod's ICI domain.

All engines operate leaf-wise on arbitrary pytrees whose leaves have leading
dim ``A = n_agents``.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from .topology import Topology

__all__ = ["mix_dense", "mix_shifts", "mix_ppermute", "make_mixer"]


def _mix_leaf_dense(W: jax.Array, x: jax.Array) -> jax.Array:
    # x: (A, ...) -> contract over agent axis.
    flat = x.reshape(x.shape[0], -1)
    out = (W.astype(flat.dtype) @ flat) if flat.dtype != jnp.bfloat16 else (
        W.astype(jnp.float32) @ flat.astype(jnp.float32)
    ).astype(jnp.bfloat16)
    return out.reshape(x.shape)


def mix_dense(topo: Topology, tree: Any) -> Any:
    """Oracle engine: explicit dense W matmul over the agent axis."""
    W = jnp.asarray(topo.dense_matrix(), dtype=jnp.float32)
    return jax.tree.map(functools.partial(_mix_leaf_dense, W), tree)


def _mix_leaf_shifts(topo: Topology, x: jax.Array) -> jax.Array:
    A = x.shape[0]
    assert A == topo.n_agents, (A, topo.n_agents)
    P, D = topo.grid_shape()
    acc = None
    for t in topo.terms:
        if t.shift == 0 or (t.level == "flat" and A == 1):
            term = x * t.weight
        elif t.level == "flat":
            term = jnp.roll(x, t.shift, axis=0) * t.weight
        else:
            # reshape agent axis to the (P, D) grid; roll the right sub-axis.
            g = x.reshape((P, D) + x.shape[1:])
            axis = 0 if t.level == "inter" else 1
            term = (jnp.roll(g, t.shift, axis=axis) * t.weight).reshape(x.shape)
        acc = term if acc is None else acc + term
    return acc


def mix_shifts(topo: Topology, tree: Any) -> Any:
    """Compiler-scheduled engine: W as a weighted sum of agent-axis rolls
    (→ collective-permute on a sharded mesh, scheduled by GSPMD)."""
    return jax.tree.map(functools.partial(_mix_leaf_shifts, topo), tree)


def _agent_axis_info(topo: Topology, mesh, agent_axes):
    """Resolve agent_axes against the mesh; returns (names, sizes, split).

    ``split`` is True when the topology's (P, D) agent grid maps 1:1 onto two
    mesh sub-axes — then inter/intra terms become single sub-axis ppermutes.
    """
    names = (tuple(agent_axes) if isinstance(agent_axes, (tuple, list))
             else (agent_axes,))
    sizes = tuple(mesh.devices.shape[mesh.axis_names.index(n)] for n in names)
    A = math.prod(sizes)
    assert A == topo.n_agents, (A, topo.n_agents)
    split = (len(names) == 2 and topo.grid is not None
             and sizes == topo.grid_shape())
    return names, sizes, split


def mix_ppermute(topo: Topology, mesh, agent_axes, tree: Any, *,
                 use_fused_kernel: bool = False,
                 interpret: bool | None = None) -> Any:
    """Production gossip engine: ``shard_map`` + ``jax.lax.ppermute``.

    The agent axis is *consumed* by the mesh (one agent per mesh slice along
    ``agent_axes``); every gossip term becomes one ppermute with a literal
    source→target list taken from :meth:`Topology.term_sources`, so the
    communication schedule is pinned rather than left to GSPMD's roll
    lowering.  Hierarchical topologies are supported two ways:

    * ``agent_axes = (pod_axis, intra_axis)`` matching ``topo.grid`` — each
      ``inter``/``intra`` term permutes only its own mesh sub-axis (cross-pod
      terms are the only DCI traffic);
    * a single flat axis — grid terms are linearized into a flat permutation
      (same wire pattern, one axis name).

    With ``use_fused_kernel=True`` the per-term weighted accumulation runs as
    one n-ary Pallas ``gossip_axpy`` combine per leaf instead of a chain of
    mul/add HBM round-trips (DESIGN §3).
    """
    from jax.sharding import PartitionSpec as P

    names, sizes, split = _agent_axis_info(topo, mesh, agent_axes)
    axis_flat = names if len(names) > 1 else names[0]
    A = topo.n_agents
    Pn, Dn = topo.grid_shape()

    def permute_term(x, t):
        if t.shift == 0 or A == 1:
            return x
        if split and t.level != "flat":
            ax, size = ((names[0], Pn) if t.level == "inter"
                        else (names[1], Dn))
            if size == 1:
                return x
            perm = [((i - t.shift) % size, i) for i in range(size)]
            return jax.lax.ppermute(x, ax, perm)
        src = topo.term_sources(t)
        perm = [(int(s), d) for d, s in enumerate(src)]
        return jax.lax.ppermute(x, axis_flat, perm)

    weights = tuple(float(t.weight) for t in topo.terms)

    def combine(payloads):
        if use_fused_kernel:
            from repro.kernels.ops import gossip_axpy
            return gossip_axpy(payloads, weights, interpret=interpret)
        acc = None
        for w, p in zip(weights, payloads):
            term = w * p
            acc = term if acc is None else acc + term
        return acc

    def body(*leaves):
        # each leaf arrives as (1, *shape) — this shard's agent replica
        return tuple(combine([permute_term(x, t) for t in topo.terms])
                     for x in leaves)

    flat, treedef = jax.tree_util.tree_flatten(tree)
    specs = tuple(P(axis_flat) for _ in flat)
    out = shard_map(body, mesh, specs, specs)(*flat)
    return jax.tree_util.tree_unflatten(treedef, list(out))


def make_mixer(topo: Topology, engine: str = "shifts", mesh=None,
               agent_axes=None, use_fused_kernel: bool = False):
    """Return ``mix(tree) -> tree``.  engine ∈ {"dense", "shifts", "ppermute"}.

    ``mesh``/``agent_axes`` are required for (and only used by) the ppermute
    engine; ``use_fused_kernel`` routes its combine through the fused Pallas
    ``gossip_axpy`` kernel.
    """
    if engine == "dense":
        return functools.partial(mix_dense, topo)
    if engine == "shifts":
        return functools.partial(mix_shifts, topo)
    if engine == "ppermute":
        assert mesh is not None and agent_axes is not None, \
            "ppermute engine needs mesh= and agent_axes="
        return functools.partial(mix_ppermute, topo, mesh, agent_axes,
                                 use_fused_kernel=use_fused_kernel)
    raise ValueError(f"unknown mixing engine: {engine}")
