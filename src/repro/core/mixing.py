"""Mixing engines: apply W to a pytree with a leading agent axis.

Three interchangeable engines (tests assert they agree to float tolerance):

* :func:`mix_dense`    — explicit ``einsum('ij,j...->i...', W, x)``.  Used for
  paper-scale simulation and as the oracle.
* :func:`mix_shifts`   — weighted sum of ``jnp.roll`` terms.  On a sharded
  agent axis XLA lowers every roll to a ``collective-permute``, but the
  schedule is GSPMD's to choose.
* :func:`mix_ppermute` — the production gossip path (DESIGN §3):
  ``shard_map`` + one explicit ``jax.lax.ppermute`` per gossip term, with the
  weighted accumulation optionally fused into a single n-ary Pallas combine
  (:func:`repro.kernels.ops.gossip_axpy`).  Hierarchical topologies decompose
  per term onto the matching mesh sub-axis, so intra-pod permutes never leave
  the pod's ICI domain.  When the topology has more agents than the mesh has
  devices (A = B·M, B > 1) the engine runs *blocked*: each device carries a
  contiguous block of B agents and every roll term decomposes into a local
  shift plus at most two boundary permutes (DESIGN §4).

All engines take one gossip *round* — a :class:`Topology`; time-varying
schedules hand the engines a different round per step through
:func:`make_schedule_mixer` (DESIGN §4).

All engines operate leaf-wise on arbitrary pytrees whose leaves have leading
dim ``A = n_agents``.  The packed parameter bus (DESIGN §5) exploits exactly
this: an ``(A, rows, 128)`` superbuffer is a one-leaf tree, so the ppermute
engine ships ONE payload per gossip term for the whole parameter set
(L·T permutes → T) and the fused combine runs once — no engine changes,
the leaf-count factor just disappears from the wire schedule.

Shard-resident gossip (DESIGN §7): with ``shard_axes`` set, leaf dim 1 (the
bus row axis) is additionally sharded over a pod-internal mesh axis (FSDP).
Gossip is agent-axis-pointwise in the row dim, so every permute stays
**shard-local**: each FSDP shard permutes only its own row block along the
agent axes and combines locally — per-device wire bytes drop by the shard
factor and no all-gather ever feeds a gossip permute.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map

from .topology import Topology

__all__ = ["mix_dense", "mix_shifts", "mix_ppermute", "mix_dense_sharded",
           "make_mixer", "make_schedule_mixer", "make_overlap_mixer",
           "build_mixer", "GroupPlan", "make_group_mixer",
           "accumulate_f32"]


def accumulate_f32(fn):
    """Wrap a tree→tree op so sub-f32 leaves accumulate in f32 and round
    once on the way out.

    The single cast-and-restore helper behind both the dense engine's bf16
    matmul path and the trainer's low-precision gossip payload
    (``RunConfig.gossip_dtype``): inputs are upcast to f32 where they are
    low-precision, ``fn`` runs, and the result is cast back to the input
    leaves' dtypes — so precision is lost exactly once, on the final store.
    """

    def wrapped(tree):
        up = jax.tree.map(
            lambda x: x.astype(jnp.float32)
            if x.dtype in (jnp.bfloat16, jnp.float16) else x, tree)
        out = fn(up)
        return jax.tree.map(lambda o, x: o.astype(x.dtype), out, tree)

    return wrapped


def _mix_leaf_dense(W: jax.Array, x: jax.Array) -> jax.Array:
    # x: (A, ...) -> contract over agent axis (f32 by accumulate_f32).
    flat = x.reshape(x.shape[0], -1)
    return (W.astype(flat.dtype) @ flat).reshape(x.shape)


def mix_dense(topo: Topology, tree: Any) -> Any:
    """Oracle engine: explicit dense W matmul over the agent axis."""
    W = jnp.asarray(topo.dense_matrix(), dtype=jnp.float32)
    return accumulate_f32(
        functools.partial(jax.tree.map, functools.partial(_mix_leaf_dense, W))
    )(tree)


def _is_masked(topo: Topology) -> bool:
    """Liveness-masked round (:class:`repro.core.elastic.MaskedTopology`)?
    Duck-typed on the per-agent weight column API so core.mixing never
    imports core.elastic."""
    return hasattr(topo, "term_weights")


def _masked_tables(topo: Topology):
    """(srcs, wcols) as (T, A) int / f32 numpy tables for a masked round."""
    srcs = np.stack([topo.term_sources(t) for t in topo.terms]).astype(np.int32)
    wcols = np.stack([topo.term_weights(t)
                      for t in topo.terms]).astype(np.float32)
    return srcs, wcols


def _mix_leaf_shifts(topo: Topology, x: jax.Array) -> jax.Array:
    A = x.shape[0]
    assert A == topo.n_agents, (A, topo.n_agents)
    if _is_masked(topo):
        # masked rounds have per-agent sources/weights — gather route
        srcs, wcols = _masked_tables(topo)
        acc = None
        for src, w in zip(srcs, wcols):
            wb = jnp.asarray(w, x.dtype).reshape((A,) + (1,) * (x.ndim - 1))
            term = x[jnp.asarray(src)] * wb
            acc = term if acc is None else acc + term
        return acc
    P, D = topo.grid_shape()
    acc = None
    for t in topo.terms:
        if t.shift == 0 or (t.level == "flat" and A == 1):
            term = x * t.weight
        elif t.level == "flat":
            term = jnp.roll(x, t.shift, axis=0) * t.weight
        else:
            # reshape agent axis to the (P, D) grid; roll the right sub-axis.
            g = x.reshape((P, D) + x.shape[1:])
            axis = 0 if t.level == "inter" else 1
            term = (jnp.roll(g, t.shift, axis=axis) * t.weight).reshape(x.shape)
        acc = term if acc is None else acc + term
    return acc


def mix_shifts(topo: Topology, tree: Any) -> Any:
    """Compiler-scheduled engine: W as a weighted sum of agent-axis rolls
    (→ collective-permute on a sharded mesh, scheduled by GSPMD)."""
    return jax.tree.map(functools.partial(_mix_leaf_shifts, topo), tree)


def _agent_axis_info(topo: Topology, mesh, agent_axes):
    """Resolve agent_axes against the mesh; returns (names, sizes, split, B).

    ``B`` is the number of agents per device (blocked mode when > 1: the
    topology's A agents live as contiguous blocks of B on M = A/B devices).
    ``split`` is True when the topology's (P, D) agent grid maps 1:1 onto two
    mesh sub-axes — then inter/intra terms become single sub-axis ppermutes.
    """
    names = (tuple(agent_axes) if isinstance(agent_axes, (tuple, list))
             else (agent_axes,))
    sizes = tuple(mesh.devices.shape[mesh.axis_names.index(n)] for n in names)
    M = math.prod(sizes)
    assert topo.n_agents % M == 0, \
        f"agent count {topo.n_agents} must be a multiple of the mesh agent " \
        f"extent {M} (axes {names})"
    B = topo.n_agents // M
    assert B == 1 or len(names) == 1, \
        "blocked gossip (agents > devices) needs a single flat agent axis"
    split = (B == 1 and len(names) == 2 and topo.grid is not None
             and sizes == topo.grid_shape())
    return names, sizes, split, B


def _flat_device_index(names, sizes):
    """This shard's flat device index along the agent axes (mixed-radix
    over multi-axis agent meshes; ``lax.axis_index`` takes one name)."""
    idx = jax.lax.axis_index(names[0])
    for n, s in zip(names[1:], sizes[1:]):
        idx = idx * s + jax.lax.axis_index(n)
    return idx


def _blocked_roll(x, shift: int, bloc: int, n_ring: int, n_dev: int,
                  axis_name):
    """Blocked circulant roll: the device-local slice of
    ``roll(x_global, shift)`` where each of ``n_ring`` consecutive devices
    holds ``bloc`` consecutive elements of one ring (rings tile the ``n_dev``
    devices contiguously — one ring per pod, or one global ring).

    Decompose shift = q·bloc + r: local rows [0, bloc−r) come from the
    device q hops back, the r boundary rows from q+1 hops back — at most two
    permutes, and parts whose hop count is ≡ 0 (mod ring) stay local, so a
    sub-block shift ships only its r boundary rows.
    """
    n_elems = bloc * n_ring
    s = shift % n_elems
    if s == 0:
        return x
    q, r = divmod(s, bloc)

    def perm(hops):
        hops %= n_ring
        pairs = []
        for d in range(n_dev):
            g, c = divmod(d, n_ring)
            pairs.append((g * n_ring + (c - hops) % n_ring, d))
        return pairs

    p1 = x[:bloc - r] if r else x
    if q % n_ring:
        p1 = jax.lax.ppermute(p1, axis_name, perm(q))
    if not r:
        return p1
    p2 = x[bloc - r:]
    if (q + 1) % n_ring:
        p2 = jax.lax.ppermute(p2, axis_name, perm(q + 1))
    return jnp.concatenate([p2, p1], axis=0)


def _make_permute_term(topo: Topology, names, sizes, split: bool, B: int):
    """The per-term wire plan of the ppermute engine: returns
    ``permute_term(x, t) -> x_permuted`` for one shard's agent block — the
    single closure behind both the synchronous ``mix_ppermute`` combine and
    the overlap pipeline's issue phase (DESIGN §6), so the two paths cannot
    drift in what they put on the wire."""
    axis_flat = names if len(names) > 1 else names[0]
    A = topo.n_agents
    M = A // B
    Pn, Dn = topo.grid_shape()

    def permute_term_blocked(x, t):
        if t.level == "flat":
            return _blocked_roll(x, t.shift, B, M, M, axis_flat)
        if t.level == "inter":
            # an inter roll by s pods is the flat roll by s·D agents
            return _blocked_roll(x, t.shift * Dn, B, M, M, axis_flat)
        if B % Dn == 0:          # whole pods per device: local roll
            g = x.reshape((B // Dn, Dn) + x.shape[1:])
            return jnp.roll(g, t.shift, axis=1).reshape(x.shape)
        assert Dn % B == 0, \
            f"blocked intra gossip needs pod size {Dn} and block {B} aligned"
        return _blocked_roll(x, t.shift, B, Dn // B, M, axis_flat)

    def permute_term(x, t):
        if t.shift == 0 or A == 1:
            return x
        if B > 1:
            return permute_term_blocked(x, t)
        if split and t.level != "flat":
            ax, size = ((names[0], Pn) if t.level == "inter"
                        else (names[1], Dn))
            if size == 1:
                return x
            perm = [((i - t.shift) % size, i) for i in range(size)]
            return jax.lax.ppermute(x, ax, perm)
        src = topo.term_sources(t)
        perm = [(int(s), d) for d, s in enumerate(src)]
        return jax.lax.ppermute(x, axis_flat, perm)

    return permute_term


def mix_ppermute(topo: Topology, mesh, agent_axes, tree: Any, *,
                 use_fused_kernel: bool = False,
                 interpret: bool | None = None,
                 transport: str = "auto",
                 shard_axes: str | None = None,
                 wire=None) -> Any:
    """Production gossip engine: ``shard_map`` + ``jax.lax.ppermute``.

    The agent axis is *consumed* by the mesh (a block of A/M agents per mesh
    slice along ``agent_axes``); every gossip term becomes at most two
    ppermutes with literal source→target lists, so the communication
    schedule is pinned rather than left to GSPMD's roll lowering.

    * One agent per device (B = 1): each term is one ppermute straight from
      :meth:`Topology.term_sources`; hierarchical topologies decompose onto
      split ``(pod, data)`` mesh axes, or linearize onto one flat axis.
    * Blocked (B > 1, the A > device-count mode): flat and inter terms run
      the blocked-roll decomposition (:func:`_blocked_roll` — local shift +
      boundary permutes, sub-block shifts ship only boundary rows); intra
      terms are fully local when each device holds whole pods, else run the
      blocked roll on the pod's device sub-ring.

    With ``use_fused_kernel=True`` the per-term weighted accumulation runs as
    one n-ary Pallas ``gossip_axpy`` combine per leaf instead of a chain of
    mul/add HBM round-trips (DESIGN §3).

    ``transport`` selects the wire mechanism (DESIGN §6 fallback matrix):
    ``"ppermute"`` forces the shard_map + ``lax.ppermute`` path above;
    ``"ring_dma"`` forces the Pallas remote-DMA ring kernel
    (:mod:`repro.kernels.ring_dma` — fuses the permute into the combine so
    payloads never round-trip HBM between the two; flat ±1 rings on a real
    TPU only); ``"auto"`` picks ring_dma when it is supported for this
    topology/mesh/payload, the fused combine was requested AND the
    operator opted in with ``REPRO_RING_DMA=1`` (the kernel follows the
    guide's RDMA pattern but is not yet validated on hardware — auto must
    not silently swap it into a production run), else ppermute.  Off-TPU
    (this container) every selection falls back to ppermute.

    ``shard_axes`` names the mesh axis FSDP-sharding leaf dim 1 (the bus
    row axis, DESIGN §7).  The permutes are unchanged — they run along the
    agent axes only — but each mesh slice now holds ``rows/S`` rows, so
    every permute and the combine operate on the shard's own row block
    (shard-local gossip; the ring_dma transport does not compose with row
    sharding and is excluded).

    ``wire`` (a :class:`repro.core.wire.WireCodec`, DESIGN §9) switches the
    engine to wire-coded payloads: ``tree`` is then the codec's *encoded*
    payload of a single ``(A, rows, 128)`` bus — a bf16 bus, or an
    ``(int8 bus, per-block scales)`` pair — whose components permute
    leaf-wise through the SAME per-term wire plan (scales travel with their
    blocks), and the decode is folded into the combine
    (:func:`repro.kernels.ops.gossip_axpy_wire` when fused, an f32
    decode-then-accumulate chain otherwise).  The result is the decoded f32
    mixed bus; since permutes commute with the elementwise decode, it
    equals the f32 engine applied to ``wire.quantize(bus)`` exactly.  The
    ring_dma transport ships raw f32 blocks and is excluded; a masked
    blocked round (B > 1) falls back to decode-then-gather (correct, but
    the gathered hop is f32 — see the §6 fallback matrix).
    """
    import os

    from jax.sharding import PartitionSpec as P

    names, sizes, split, B = _agent_axis_info(topo, mesh, agent_axes)
    axis_flat = names if len(names) > 1 else names[0]
    A = topo.n_agents
    permute_term = _make_permute_term(topo, names, sizes, split, B)
    if wire is not None and wire.fmt == "f32":
        wire = None     # f32 wire IS the legacy path — byte-identical
    if wire is not None:
        tree = tuple(wire.payload_leaves(tree))
    if shard_axes is not None:
        assert shard_axes not in names, (shard_axes, names)
        assert B == 1, "shard-resident gossip needs one agent per mesh slice"
        for l in jax.tree.leaves(tree):
            assert getattr(l, "ndim", 0) >= 2, \
                "shard_axes shards leaf dim 1 — leaves need >= 2 dims"

    masked = _is_masked(topo)
    assert transport in ("auto", "ppermute", "ring_dma"), transport
    ring_plan = None
    if transport != "ppermute":
        from repro.kernels import ring_dma
        eligible = (shard_axes is None and not masked and wire is None
                    and ring_dma.ring_dma_supported(topo, n_axes=len(names),
                                                    B=B)
                    and all(getattr(l, "ndim", 0) == 3 and l.shape[-1] == 128
                            for l in jax.tree.leaves(tree)))
        if transport == "ring_dma":
            assert eligible, (
                "transport='ring_dma' needs a flat ±1-ring topology, one "
                "agent per device on a single mesh axis, (A, rows, 128) "
                "payloads and a real TPU backend")
        opted_in = os.environ.get("REPRO_RING_DMA", "") == "1"
        if eligible and (transport == "ring_dma"
                         or (use_fused_kernel and opted_in)):
            ring_plan = ring_dma.ring_plan(topo)

    weights = tuple(float(t.weight) for t in topo.terms)
    if masked:
        srcs_np, wcols_np = _masked_tables(topo)

    def combine(payloads, ws):
        if use_fused_kernel:
            from repro.kernels.ops import gossip_axpy
            return gossip_axpy(payloads, ws, interpret=interpret)
        acc = None
        for w, p in zip(ws, payloads):
            term = w * p
            acc = term if acc is None else acc + term
        return acc

    def masked_gather_mix(x):
        # blocked masked fallback (DESIGN §8): per-agent source maps do not
        # decompose into blocked rolls, so gather the agent axis and index.
        xg = jax.lax.all_gather(x, axis_flat, axis=0, tiled=True)  # (A, ...)
        agents = _flat_device_index(names, sizes) * B + jnp.arange(B)
        acc = None
        for src, w in zip(jnp.asarray(srcs_np), jnp.asarray(wcols_np)):
            wb = w[agents].reshape((B,) + (1,) * (x.ndim - 1))
            term = xg[src[agents]] * wb.astype(x.dtype)
            acc = term if acc is None else acc + term
        return acc

    def body(*leaves):
        # each leaf arrives as (B, *shape) — this shard's agent block
        if ring_plan is not None:
            from repro.kernels import ring_dma
            return tuple(
                ring_dma.ring_combine_shard(x, ring_plan,
                                            axis_name=axis_flat, n_devices=A)
                for x in leaves)
        if masked and B > 1:
            return tuple(masked_gather_mix(x) for x in leaves)
        if masked:
            # B = 1: the permutes come straight from the masked source maps
            # (the generic term_sources branch of the wire plan); only the
            # weights become per-agent — this device's weight column.
            i = _flat_device_index(names, sizes)
            wcols = jnp.asarray(wcols_np)
            ws = [wcols[k, i] for k in range(len(topo.terms))]
            return tuple(
                combine([permute_term(x, t) for t in topo.terms], ws)
                for x in leaves)
        return tuple(combine([permute_term(x, t) for t in topo.terms],
                             weights)
                     for x in leaves)

    def combine_wire(pays, ws):
        # decode folded into the combine: payloads widen to f32 exactly
        # once, already weighted/dequantized (DESIGN §9).
        if use_fused_kernel:
            from repro.kernels.ops import gossip_axpy_wire
            return gossip_axpy_wire(pays, ws, fmt=wire.fmt,
                                    block_rows=wire.block_rows,
                                    interpret=interpret)
        acc = None
        for w, p in zip(ws, pays):
            term = w * wire.decode(p)
            acc = term if acc is None else acc + term
        return acc

    def body_wire(*leaves):
        payload = wire.payload_from_leaves(leaves)
        if masked and B > 1:
            # blocked masked fallback: gather needs per-agent indexing, so
            # decode shard-locally first (that hop ships f32; §6 matrix).
            return (masked_gather_mix(wire.decode(payload)),)
        if masked:
            i = _flat_device_index(names, sizes)
            wcols = jnp.asarray(wcols_np)
            ws = [wcols[k, i] for k in range(len(topo.terms))]
        else:
            ws = weights
        pays = [wire.map_payload(lambda l: permute_term(l, t), payload)
                for t in topo.terms]
        return (combine_wire(pays, ws),)

    spec = P(axis_flat) if shard_axes is None else P(axis_flat, shard_axes)
    if wire is not None:
        specs = tuple(spec for _ in tree)
        (out,) = shard_map(body_wire, mesh, specs, (spec,))(*tree)
        return out

    flat, treedef = jax.tree_util.tree_flatten(tree)
    specs = tuple(spec for _ in flat)
    out = shard_map(body, mesh, specs, specs)(*flat)
    return jax.tree_util.tree_unflatten(treedef, list(out))


def mix_dense_sharded(topo: Topology, mesh, agent_axes, shard_axes,
                      tree: Any) -> Any:
    """Shard-resident dense oracle (DESIGN §7): ``W x`` under the same
    ``P(agent_axes, shard_axes)`` layout the sharded ppermute engine uses.

    Each shard all-gathers its OWN row block along the agent axis only
    (never the shard axis), applies the dense W to the gathered
    ``(A, rows/S, ...)`` stack, and keeps its own agent's result — so the
    oracle stays row-sharded end to end and the sharded equivalence test
    ``mix_ppermute == mix_dense_sharded == mix_dense`` runs under a real
    pods × shards host mesh without materializing a replica.
    """
    from jax.sharding import PartitionSpec as P

    names, _, _, B = _agent_axis_info(topo, mesh, agent_axes)
    assert B == 1, "shard-resident dense oracle needs one agent per slice"
    axis_flat = names if len(names) > 1 else names[0]
    A = topo.n_agents
    W = jnp.asarray(topo.dense_matrix(), dtype=jnp.float32)

    def body(x):
        # x: (1, rows/S, ...) — this agent's row block on this shard
        gathered = jax.lax.all_gather(x[0], axis_flat)   # (A, rows/S, ...)
        flat = gathered.reshape(A, -1).astype(jnp.float32)
        mixed = (W @ flat).reshape(gathered.shape).astype(x.dtype)
        idx = jax.lax.axis_index(axis_flat)
        return jax.lax.dynamic_slice_in_dim(mixed, idx, 1, axis=0)

    spec = P(axis_flat, shard_axes)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    out = [shard_map(body, mesh, (spec,), spec)(l) for l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def make_mixer(topo: Topology, engine: str = "shifts", mesh=None,
               agent_axes=None, use_fused_kernel: bool = False,
               transport: str = "auto", shard_axes: str | None = None,
               wire=None):
    """Return ``mix(tree) -> tree``.  engine ∈ {"dense", "shifts", "ppermute"}.

    ``mesh``/``agent_axes`` are required for (and only used by) the ppermute
    engine; ``use_fused_kernel`` routes its combine through the fused Pallas
    ``gossip_axpy`` kernel, ``transport`` selects its wire mechanism and
    ``shard_axes`` enables shard-resident gossip over FSDP row shards
    (see :func:`mix_ppermute`).

    With ``wire`` (a :class:`repro.core.wire.WireCodec`) the mixer takes the
    codec's *encoded* payload and returns the decoded f32 mix.  Only the
    ppermute engine actually ships wire bytes; dense/shifts decode first and
    mix in f32 — the single-device reference of the identical semantics
    (the engines still agree exactly, payload-in, f32-out).
    """
    if wire is not None and wire.fmt == "f32":
        wire = None
    if engine == "dense":
        base = functools.partial(mix_dense, topo)
        if wire is None:
            return base
        return lambda payload: base(wire.decode(payload))
    if engine == "shifts":
        base = functools.partial(mix_shifts, topo)
        if wire is None:
            return base
        return lambda payload: base(wire.decode(payload))
    if engine == "ppermute":
        assert mesh is not None and agent_axes is not None, \
            "ppermute engine needs mesh= and agent_axes="
        return functools.partial(mix_ppermute, topo, mesh, agent_axes,
                                 use_fused_kernel=use_fused_kernel,
                                 transport=transport, shard_axes=shard_axes,
                                 wire=wire)
    raise ValueError(f"unknown mixing engine: {engine}")


def make_schedule_mixer(sched, engine: str = "shifts", mesh=None,
                        agent_axes=None, use_fused_kernel: bool = False,
                        shard_axes: str | None = None, wire=None):
    """Step-indexed mixer over a :class:`~repro.core.schedule.GossipSchedule`:
    returns ``mix(tree, step=0) -> tree`` applying the schedule's round
    ``step % period`` through the chosen engine.

    Every round gets its own engine closure (its own permute plan / kernel
    arity); a concrete ``step`` dispatches in Python, a traced one through
    ``jax.lax.switch`` — the round index is replicated (it derives from the
    global step), so the branch collectives stay SPMD-consistent.  Period-1
    schedules skip the switch entirely and are bit-identical to the static
    ``make_mixer`` path.

    The step→round map is the schedule's ``round_index`` — plain schedules
    fold the step mod the period; an
    :class:`~repro.core.elastic.ElasticSchedule` additionally selects the
    liveness epoch, so churn rides through here with no engine changes.
    """
    mixers = [make_mixer(r, engine, mesh=mesh, agent_axes=agent_axes,
                         use_fused_kernel=use_fused_kernel,
                         shard_axes=shard_axes, wire=wire)
              for r in sched.rounds]
    if len(mixers) == 1:
        return lambda tree, step=0: mixers[0](tree)

    def mix(tree, step=0):
        r = sched.round_index(step)
        if isinstance(r, (int, np.integer)):
            return mixers[int(r)](tree)
        return jax.lax.switch(r, mixers, tree)

    return mix


def make_overlap_mixer(sched, engine: str = "ppermute", mesh=None,
                       agent_axes=None, use_fused_kernel: bool = False,
                       interpret: bool | None = None,
                       shard_axes: str | None = None, wire=None):
    """Phase-split schedule mixer for the overlapped gossip pipeline
    (DESIGN §6): returns ``(issue, complete)`` such that
    ``complete(issue(x, step), step)`` equals the synchronous
    ``make_schedule_mixer(...)(x, step)`` for a single-array payload ``x``
    (the packed bus).

    ``issue`` runs ONLY the round's collective permutes — no arithmetic —
    and returns a ``(K, A, ...)`` stack of per-term payloads, where
    ``K = max arity over rounds``; shorter rounds pad the stack with the
    unpermuted payload under weight 0, so every round shares one stack
    shape (a traced-step ``lax.switch`` needs that) and one combine kernel
    arity.  ``complete`` runs only the weighted n-ary combine (the fused
    ``gossip_axpy`` when requested).  Everything the caller places between
    the two calls — the backward pass, in the trainer — is
    data-independent of the in-flight permutes, which is exactly the
    window XLA's latency-hiding scheduler uses to take the wire off the
    critical path.

    For the ``dense``/``shifts`` engines there is no separable wire phase:
    ``issue`` is the identity and ``complete`` the full mix, so the delayed
    pipeline's *algorithmic* semantics (gradients at the pre-mix iterate)
    are engine-independent and single-device-testable even though only the
    ppermute engine gains overlap.

    Straggler degradation (DESIGN §8): ``complete(payloads, step, late=)``
    takes an optional ``(K,)`` bool mask of LATE payload slots
    (:meth:`repro.core.elastic.StragglerPlan.late_at`).  A late slot's
    payload is replaced by the round's SELF payload under the slot's
    original weight *before* the combine — the self-weight absorption
    ``W_eff = Σ_{k∉late} w_k P_k + (Σ_{k∈late} w_k) I``, which keeps W_eff
    doubly stochastic with positive diagonal and never reads the late
    (possibly garbage) buffer, so a straggler degrades mixing instead of
    blocking or NaNing the step.  Rounds without an explicit self term use
    a weight-0 pad slot, which always holds the unpermuted (self) payload.
    The dense engine supports ``late`` through an explicit per-term W_eff
    oracle (the straggler tests' reference); shifts has no payload stack
    and rejects it.  ``complete.n_terms`` exposes the stack arity K for
    :class:`~repro.core.elastic.StragglerPlan` validation.

    With ``wire`` (a :class:`repro.core.wire.WireCodec`, DESIGN §9) the
    pipeline composes with the compressed wire: ``issue`` takes the codec's
    *encoded* payload (quantized at issue time, behind the backward pass —
    the residual was split off by the EF encode before the call) and stacks
    each payload component per term; ``complete`` folds the decode into the
    combine and returns the f32 mixed bus.  Late-slot substitution operates
    on the encoded stacks component-wise, so a straggler degrades onto its
    own *quantized* self payload — exactly what it put on the wire.
    """
    if wire is not None and wire.fmt == "f32":
        wire = None
    R = len(sched.rounds)
    K = max(len(r.terms) for r in sched.rounds)

    def self_index(topo):
        si = next((k for k, t in enumerate(topo.terms) if t.shift == 0),
                  len(topo.terms))
        assert si < K, \
            f"{topo.name}: no self term and no pad slot to degrade onto"
        return si

    if engine != "ppermute":
        mix = make_schedule_mixer(sched, engine, mesh=mesh,
                                  agent_axes=agent_axes,
                                  use_fused_kernel=use_fused_kernel,
                                  shard_axes=shard_axes, wire=wire)
        if engine == "dense":
            # per-term dense stacks: Wk = diag(wcol_k) P_k, Ik = diag(wcol_k)
            n = sched.n_agents
            Wk_np = np.zeros((R, K, n, n), np.float32)
            Ik_np = np.zeros((R, K, n, n), np.float32)
            idx = np.arange(n)
            for r, topo in enumerate(sched.rounds):
                for k, t in enumerate(topo.terms):
                    wcol = (topo.term_weights(t) if _is_masked(topo)
                            else np.full(n, t.weight))
                    Wk_np[r, k, idx, topo.term_sources(t)] = wcol
                    Ik_np[r, k, idx, idx] = wcol
            Wk_t, Ik_t = jnp.asarray(Wk_np), jnp.asarray(Ik_np)

        def complete(x, step=0, late=None):
            if late is None:
                return mix(x, step)
            assert engine == "dense", \
                "straggler degradation needs the ppermute or dense engine"
            if wire is not None:
                x = wire.decode(x)
            r = sched.round_index(step)
            lateb = jnp.asarray(late).reshape(K, 1, 1)
            W_eff = jnp.sum(jnp.where(lateb, Ik_t[r], Wk_t[r]), axis=0)
            return accumulate_f32(functools.partial(
                jax.tree.map, functools.partial(_mix_leaf_dense, W_eff)))(x)

        complete.n_terms = K
        return (lambda x, step=0: x), complete

    from jax.sharding import PartitionSpec as P

    assert mesh is not None and agent_axes is not None, \
        "overlap mixer needs mesh= and agent_axes= for the ppermute engine"
    A = sched.n_agents
    any_masked = any(_is_masked(r) for r in sched.rounds)

    names0, _, _, B0 = _agent_axis_info(sched.rounds[0], mesh, agent_axes)
    axis0 = names0 if len(names0) > 1 else names0[0]
    if any_masked:
        assert B0 == 1, \
            "masked overlap gossip needs one agent per mesh slice (B = 1)"

    # weight table: (R, K) replicated normally; per-agent (R, K, A) columns
    # sharded over the agent axis when any round is liveness-masked.
    if any_masked:
        w_np = np.zeros((R, K, A), np.float32)
        for r, topo in enumerate(sched.rounds):
            for k, t in enumerate(topo.terms):
                w_np[r, k] = (topo.term_weights(t) if _is_masked(topo)
                              else t.weight)
        w_spec = P(None, axis0)
    else:
        w_np = np.zeros((R, K), np.float32)
        for r, topo in enumerate(sched.rounds):
            w_np[r, :len(topo.terms)] = [t.weight for t in topo.terms]
        w_spec = P()
    w_table = jnp.asarray(w_np)
    self_np = np.asarray([self_index(r) for r in sched.rounds], np.int32)
    self_t = jnp.asarray(self_np)

    def make_issue(topo):
        names, sizes, split, B = _agent_axis_info(topo, mesh, agent_axes)
        axis_flat = names if len(names) > 1 else names[0]
        if shard_axes is not None:
            assert B == 1, \
                "shard-resident gossip needs one agent per mesh slice"
        permute_term = _make_permute_term(topo, names, sizes, split, B)

        def stack_terms(x):
            pays = [permute_term(x, t) for t in topo.terms]
            pays += [x] * (K - len(pays))   # weight-0 pad to the max arity
            return jnp.stack(pays)

        in_spec = (P(axis_flat) if shard_axes is None
                   else P(axis_flat, shard_axes))
        out_spec = (P(None, axis_flat) if shard_axes is None
                    else P(None, axis_flat, shard_axes))
        if wire is None:
            return shard_map(stack_terms, mesh, (in_spec,), out_spec)

        # wire-coded issue: stack every payload component per term — the
        # permutes run on the wire dtype, scales ride with their blocks.
        def body_wire(*leaves):
            return tuple(stack_terms(l) for l in leaves)

        nl = 2 if wire.fmt == "int8" else 1
        sm = shard_map(body_wire, mesh, (in_spec,) * nl, (out_spec,) * nl)
        return lambda payload: wire.payload_from_leaves(
            sm(*wire.payload_leaves(payload)))

    issues = [make_issue(r) for r in sched.rounds]

    def issue(x, step=0):
        if R == 1:
            return issues[0](x)
        r = sched.round_index(step)
        if isinstance(r, (int, np.integer)):
            return issues[int(r)](x)
        return jax.lax.switch(r, issues, x)

    def combine_body(w, p):
        # p: (K, B_shard, ...) payload stack for this shard's agent block;
        # w: (K,) replicated round weights, or this agent's (K, 1) column
        # when the schedule carries masked rounds.
        ops = [p[k] for k in range(K)]
        ws = [w[k] if w.ndim == 1 else w[k, 0] for k in range(K)]
        if use_fused_kernel:
            from repro.kernels.ops import gossip_axpy
            return gossip_axpy(ops, ws, interpret=interpret)
        acc = ws[0] * ops[0]
        for k in range(1, K):
            acc = acc + ws[k] * ops[k]
        return acc

    def combine_body_wire(w, *pleaves):
        # pleaves: per-component (K, B_shard, ...) stacks; regroup per term
        # and fold the decode into the weighted combine (DESIGN §9).
        ws = [w[k] if w.ndim == 1 else w[k, 0] for k in range(K)]
        ops = [wire.payload_from_leaves([leaf[k] for leaf in pleaves])
               for k in range(K)]
        if use_fused_kernel:
            from repro.kernels.ops import gossip_axpy_wire
            return gossip_axpy_wire(ops, ws, fmt=wire.fmt,
                                    block_rows=wire.block_rows,
                                    interpret=interpret)
        acc = None
        for wk, op in zip(ws, ops):
            term = wk * wire.decode(op)
            acc = term if acc is None else acc + term
        return acc

    pay_spec = (P(None, axis0) if shard_axes is None
                else P(None, axis0, shard_axes))
    out0 = P(axis0) if shard_axes is None else P(axis0, shard_axes)
    if wire is None:
        combine = shard_map(combine_body, mesh, (w_spec, pay_spec), out0)
    else:
        nl = 2 if wire.fmt == "int8" else 1
        combine_sm = shard_map(combine_body_wire, mesh,
                               (w_spec,) + (pay_spec,) * nl, out0)

        def combine(w, payloads):
            return combine_sm(w, *wire.payload_leaves(payloads))

    def complete(payloads, step=0, late=None):
        r = sched.round_index(step)
        if late is not None:
            # substitute late slots with the round's self payload BEFORE
            # the combine — original weights then realize the self-weight
            # absorption W_eff without ever reading the late buffer.  With
            # a wire codec this runs component-wise on the encoded stacks.
            def sub(pay):
                if isinstance(r, (int, np.integer)):
                    selfpay = pay[int(self_np[r])]
                else:
                    selfpay = jnp.take(pay, self_t[r], axis=0)
                lateb = jnp.asarray(late).reshape(
                    (K,) + (1,) * (pay.ndim - 1))
                return jnp.where(lateb, selfpay[None], pay)

            payloads = jax.tree.map(sub, payloads)
        return combine(w_table[r], payloads)

    complete.n_terms = K
    return issue, complete


# ---------------------------------------------------------------------------
# unified mixer factory + policy-group mixer (DESIGN §12)
# ---------------------------------------------------------------------------

def build_mixer(sched, *, mode: str = "schedule", engine: str = "shifts",
                mesh=None, agent_axes=None, use_fused_kernel: bool = False,
                interpret: bool | None = None, transport: str = "auto",
                shard_axes: str | None = None, wire=None):
    """Single mixer entry point over the three construction modes.

    ``mode="static"`` takes one :class:`~repro.core.topology.Topology` (or
    a period-1 schedule) and returns ``mix(tree) -> tree``
    (:func:`make_mixer`); ``mode="schedule"`` takes a
    :class:`~repro.core.schedule.GossipSchedule` (a bare topology is
    wrapped static) and returns ``mix(tree, step=0)``
    (:func:`make_schedule_mixer`); ``mode="overlap"`` returns the
    ``(issue, complete)`` phase-split pair (:func:`make_overlap_mixer`).
    The legacy ``make_*`` names stay as thin aliases of this factory's
    modes — new call sites should come through here.
    """
    rounds = getattr(sched, "rounds", None)
    if mode == "static":
        topo = sched
        if rounds is not None:
            assert len(rounds) == 1, \
                f"mode='static' needs a topology or a period-1 schedule, " \
                f"got period {len(rounds)}"
            topo = rounds[0]
        return make_mixer(topo, engine, mesh=mesh, agent_axes=agent_axes,
                          use_fused_kernel=use_fused_kernel,
                          transport=transport, shard_axes=shard_axes,
                          wire=wire)
    if rounds is None:
        from .schedule import StaticSchedule
        sched = StaticSchedule(sched)
    if mode == "schedule":
        return make_schedule_mixer(sched, engine, mesh=mesh,
                                   agent_axes=agent_axes,
                                   use_fused_kernel=use_fused_kernel,
                                   shard_axes=shard_axes, wire=wire)
    if mode == "overlap":
        return make_overlap_mixer(sched, engine, mesh=mesh,
                                  agent_axes=agent_axes,
                                  use_fused_kernel=use_fused_kernel,
                                  interpret=interpret,
                                  shard_axes=shard_axes, wire=wire)
    raise ValueError(f"unknown mixer mode: {mode!r} "
                     "(expected 'static', 'schedule' or 'overlap')")


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One policy group's resolved mixing plan: the layout's
    :class:`~repro.core.bus.BusGroup` (row range + cadence), the group's
    own :class:`~repro.core.schedule.GossipSchedule` (``None`` for a full
    opt-out) and an optional per-group wire codec (stateless
    quantize-on-the-wire; the error-feedback wire stays run-level)."""

    group: Any
    sched: Any = None
    wire: Any = None


def make_group_mixer(plans, *, engine: str = "ppermute", mesh=None,
                     agent_axes=None, use_fused_kernel: bool = False,
                     shard_axes: str | None = None):
    """Group-aware bus mixer (DESIGN §12): ``mix(bus, step=0) -> bus``.

    ``plans`` must cover the full ``(A, rows, 128)`` bus with contiguous
    row ranges.  Each step issues one permute plan per *active* group:

    * ``gossip_every == 0`` (opt-out) groups are pure slices — no mixer is
      ever built for their rows, so they contribute ZERO collectives to
      the lowered HLO (pinned by test);
    * ``gossip_every == k > 1`` groups mix only on steps with
      ``step % k == k-1``, on their own round clock ``step // k`` so the
      skip cadence cannot gcd-alias schedule rounds away; off-steps lower
      through ``lax.cond`` (or a Python branch for concrete steps) and
      ship nothing;
    * every-step groups apply their schedule round at ``step`` directly.

    Each group's sub-mixer sees the group's row slice as a one-leaf tree,
    so it reuses the unmodified engines — per-group schedules, wire
    codecs and masked rounds all compose exactly as on the whole-bus
    path.  The mixed slices are reassembled by row-order concatenation.
    """
    plans = sorted(plans, key=lambda p: p.group.row)
    segments = []  # (row, rows, apply(bus_seg, step) -> seg)
    cursor = 0
    for plan in plans:
        g = plan.group
        assert g.row == cursor, \
            f"group {g.name!r} rows not contiguous: starts at {g.row}, " \
            f"expected {cursor}"
        cursor = g.row + g.rows
        if g.rows == 0:
            continue
        if g.gossip_every == 0 or plan.sched is None:
            segments.append((g.row, g.rows, None))
            continue
        inner = make_schedule_mixer(plan.sched, engine, mesh=mesh,
                                    agent_axes=agent_axes,
                                    use_fused_kernel=use_fused_kernel,
                                    shard_axes=shard_axes, wire=plan.wire)
        k = g.gossip_every
        if k == 1:
            segments.append((g.row, g.rows, inner))
            continue

        def gated(seg, step, inner=inner, k=k):
            gstep = step // k
            if isinstance(step, (int, np.integer)):
                return inner(seg, gstep) if step % k == k - 1 else seg
            return jax.lax.cond(step % k == k - 1,
                                lambda s: inner(s, gstep),
                                lambda s: s, seg)

        segments.append((g.row, g.rows, gated))

    def mix(bus, step=0):
        assert bus.ndim == 3, bus.shape
        assert cursor == bus.shape[1], (cursor, bus.shape)
        out = []
        for row, rows, apply in segments:
            seg = jax.lax.slice_in_dim(bus, row, row + rows, axis=1)
            out.append(seg if apply is None else apply(seg, step))
        return out[0] if len(out) == 1 else jnp.concatenate(out, axis=1)

    return mix
