"""Mixing engines: apply W to a pytree with a leading agent axis.

Two interchangeable engines (tests assert they agree to float tolerance):

* :func:`mix_dense`  — explicit ``einsum('ij,j...->i...', W, x)``.  Used for
  paper-scale simulation and as the oracle.
* :func:`mix_shifts` — weighted sum of ``jnp.roll`` terms.  On a sharded agent
  axis XLA lowers every roll to a ``collective-permute`` — this is the
  production gossip path (DESIGN §3).

Both operate leaf-wise on arbitrary pytrees whose leaves have leading dim
``A = n_agents``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .topology import Topology

__all__ = ["mix_dense", "mix_shifts", "mix_ppermute", "make_mixer"]


def _mix_leaf_dense(W: jax.Array, x: jax.Array) -> jax.Array:
    # x: (A, ...) -> contract over agent axis.
    flat = x.reshape(x.shape[0], -1)
    out = (W.astype(flat.dtype) @ flat) if flat.dtype != jnp.bfloat16 else (
        W.astype(jnp.float32) @ flat.astype(jnp.float32)
    ).astype(jnp.bfloat16)
    return out.reshape(x.shape)


def mix_dense(topo: Topology, tree: Any) -> Any:
    """Oracle engine: explicit dense W matmul over the agent axis."""
    W = jnp.asarray(topo.dense_matrix(), dtype=jnp.float32)
    return jax.tree.map(functools.partial(_mix_leaf_dense, W), tree)


def _mix_leaf_shifts(topo: Topology, x: jax.Array) -> jax.Array:
    A = x.shape[0]
    assert A == topo.n_agents, (A, topo.n_agents)
    if topo.grid is not None:
        P, D = topo.grid
    else:
        P, D = 1, A
    acc = None
    for t in topo.terms:
        if t.shift == 0 or (t.level == "flat" and A == 1):
            term = x * t.weight
        elif t.level == "flat":
            term = jnp.roll(x, t.shift, axis=0) * t.weight
        else:
            # reshape agent axis to the (P, D) grid; roll the right sub-axis.
            g = x.reshape((P, D) + x.shape[1:])
            axis = 0 if t.level == "inter" else 1
            term = (jnp.roll(g, t.shift, axis=axis) * t.weight).reshape(x.shape)
        acc = term if acc is None else acc + term
    return acc


def mix_shifts(topo: Topology, tree: Any) -> Any:
    """Production engine: W as a weighted sum of agent-axis rolls
    (→ collective-permute on a sharded mesh)."""
    return jax.tree.map(functools.partial(_mix_leaf_shifts, topo), tree)


def mix_ppermute(topo: Topology, mesh, agent_axes, tree: Any) -> Any:
    """Explicit-collective engine: ``shard_map`` + ``jax.lax.ppermute``.

    The agent axis is *consumed* by the mesh (one agent per mesh slice along
    ``agent_axes``); every gossip term becomes one ppermute with a literal
    source→target ring.  This is the manual-control twin of :func:`mix_shifts`
    (which leaves the permute scheduling to GSPMD) — useful when the compiler's
    roll lowering must be pinned, and as an executable spec of the paper's
    communication pattern.  Leaves must carry the leading agent axis; only
    "flat" topologies are supported (hierarchical ones decompose into two
    nested calls).
    """
    from jax.sharding import PartitionSpec as P

    names = agent_axes if isinstance(agent_axes, tuple) else (agent_axes,)
    A = 1
    for n in names:
        A *= mesh.devices.shape[mesh.axis_names.index(n)]
    assert A == topo.n_agents, (A, topo.n_agents)
    assert all(t.level == "flat" for t in topo.terms), \
        "ppermute engine supports flat (circulant) topologies"
    axis = names if len(names) > 1 else names[0]

    def body(*leaves):
        out = []
        for x in leaves:
            # x: (1, *shape) — this shard's agent replica
            acc = None
            for t in topo.terms:
                if t.shift == 0:
                    term = x * t.weight
                else:
                    perm = [((i - t.shift) % A, i) for i in range(A)]
                    term = jax.lax.ppermute(x, axis, perm) * t.weight
                acc = term if acc is None else acc + term
            out.append(acc)
        return tuple(out)

    flat, treedef = jax.tree_util.tree_flatten(tree)
    specs = tuple(P(axis) for _ in flat)
    out = jax.shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                        check_vma=False)(*flat)
    return jax.tree_util.tree_unflatten(treedef, list(out))


def make_mixer(topo: Topology, engine: str = "shifts", mesh=None,
               agent_axes=None):
    """Return ``mix(tree) -> tree``.  engine ∈ {"dense", "shifts", "ppermute"}."""
    if engine == "dense":
        return functools.partial(mix_dense, topo)
    if engine == "shifts":
        return functools.partial(mix_shifts, topo)
    if engine == "ppermute":
        assert mesh is not None and agent_axes is not None
        return functools.partial(mix_ppermute, topo, mesh, agent_axes)
    raise ValueError(f"unknown mixing engine: {engine}")
