"""Step-indexed gossip schedules: time-varying mixing matrices W(t).

EDM's analysis fixes one mixing matrix W, but the fastest practical
decentralized systems gossip over *time-varying* graphs: a schedule maps
``step -> round`` where each round is itself a :class:`~repro.core.topology.
Topology` — it carries its own ``ShiftTerm`` set, dense oracle matrix and
(through ``term_sources``) ppermute plan, so every mixing engine consumes a
round unchanged (DESIGN §4).

Shipped schedules:

* :class:`StaticSchedule` — period 1, wraps one topology; bit-identical to
  the pre-schedule behavior.
* :class:`RoundRobinExp` — one-peer-per-round exponential graph (Assran et
  al. 2019; Ying et al. 2021; the setting of Takezawa et al.'s Momentum
  Tracking): round j gossips only over offset 2^j, so each step is ONE
  collective-permute instead of the O(log n) of the static exp graph, while
  the period product still mixes at (power-of-two n: better than) the
  static rate — for n = 2^k the product is *exact averaging*.
* :class:`AlternatingHierarchical` — intra-pod rounds (fast ICI) interleaved
  with sparse inter-pod rounds (slow DCI), for multi-pod meshes.

Assumption-1 transfer: per-round matrices are doubly stochastic with
positive diagonal (the one-peer rounds are asymmetric, which the paper's
per-step Assumption 1 does not require of a *schedule*); the contract that
makes EDM's guarantees transfer is on the **period product**
``W(p-1) ... W(0)`` — doubly stochastic with spectral gap > 0 — which
:meth:`GossipSchedule.check_assumption1` enforces for every shipped
schedule (tests/test_gossip_engines.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .topology import (ShiftTerm, Topology, exp_graph, hierarchical,
                       matrix_lam, ring)

__all__ = [
    "GossipSchedule", "StaticSchedule", "RoundRobinExp",
    "AlternatingHierarchical", "make_schedule", "SCHEDULES",
    "term_wire_rows", "wire_bytes_per_step", "group_wire_bytes_per_step",
]


@dataclasses.dataclass(frozen=True)
class GossipSchedule:
    """A periodic sequence of gossip rounds; ``round(step)`` indexes it.

    ``rounds[r]`` is a full :class:`Topology`, so the dense oracle, the
    shift engine and the ppermute plan of round r all derive from the same
    ``ShiftTerm`` set — the engines cannot drift from the oracle at any
    round index.
    """

    name: str
    n_agents: int
    rounds: Tuple[Topology, ...]

    @property
    def period(self) -> int:
        return len(self.rounds)

    def round_index(self, step: int):
        """Round index for global step ``step`` (works on traced ints)."""
        return step % self.period

    def round(self, step: int) -> Topology:
        """The mixing topology W(t) applied at global step ``step``."""
        return self.rounds[int(step) % self.period]

    # ---- period-product spectral properties ------------------------------
    def period_product(self) -> np.ndarray:
        """Dense product W(p-1) @ ... @ W(0) — the per-period mixing map."""
        W = np.eye(self.n_agents)
        for topo in self.rounds:
            W = topo.dense_matrix() @ W
        return W

    def product_lam(self) -> float:
        """Second largest eigenvalue modulus of the period product (the
        product is not symmetric in general, so moduli — not eigvalsh)."""
        return matrix_lam(self.period_product())

    def product_spectral_gap(self) -> float:
        return 1.0 - self.product_lam()

    def product_spectral_stats(self) -> dict:
        W = self.period_product()
        return {
            "name": self.name,
            "n": self.n_agents,
            "period": self.period,
            "lambda": matrix_lam(W),
            "gap": 1.0 - matrix_lam(W),
            "permutes_per_step": max(
                sum(1 for t in r.terms if t.shift != 0) for r in self.rounds),
        }

    # ---- Assumption 1 transfer -------------------------------------------
    def check_assumption1(self, atol: float = 1e-10) -> None:
        """Schedule form of the paper's Assumption 1: every round is doubly
        stochastic with nonnegative entries and positive diagonal, and the
        period product has spectral gap > 0 (so consensus contracts every
        period and EDM's bounds apply with λ = product λ^(1/p))."""
        n = self.n_agents
        ones = np.ones(n)
        for r, topo in enumerate(self.rounds):
            W = topo.dense_matrix()
            assert np.allclose(W @ ones, ones, atol=atol), \
                f"{self.name} round {r}: W 1 != 1"
            assert np.allclose(ones @ W, ones, atol=atol), \
                f"{self.name} round {r}: 1ᵀ W != 1ᵀ"
            assert np.all(W >= -atol), f"{self.name} round {r}: negative w_ij"
            assert np.all(np.diag(W) > 0), f"{self.name} round {r}: w_ii = 0"
        if n > 1:
            gap = self.product_spectral_gap()
            assert gap > atol, \
                f"{self.name}: period product not contracting (gap={gap})"


class StaticSchedule(GossipSchedule):
    """Period-1 schedule wrapping one fixed topology (today's behavior)."""

    def __init__(self, topo: Topology):
        super().__init__(name=f"static({topo.name})", n_agents=topo.n_agents,
                         rounds=(topo,))


class RoundRobinExp(GossipSchedule):
    """One-peer round-robin exponential schedule.

    Round j applies  W_j = ½ I + ½ R_{o_j}  with the offsets o_j cycling
    through the powers of two {1, 2, 4, ..., 2^(L-1)}, L = ⌈log₂ n⌉: one
    nonzero-shift term — one collective-permute — per step, an O(log n)×
    per-step wire-byte cut over the static exp graph.  The rounds are
    circulant and therefore commute, so the period product is independent
    of the offset order; for n a power of two it equals (1/n)·11ᵀ — exact
    averaging every L steps.  ``seed`` shuffles the offset order (a wire-
    schedule knob: it changes which link is hot when, never the product).
    """

    def __init__(self, n: int, seed: Optional[int] = None):
        offsets = []
        j = 1
        while j < n:
            offsets.append(j)
            j *= 2
        if not offsets:
            offsets = [0]
        if seed is not None:
            offsets = list(np.random.default_rng(seed).permutation(offsets))
        rounds = []
        for o in offsets:
            if o == 0:
                terms: Tuple[ShiftTerm, ...] = (ShiftTerm("flat", 0, 1.0),)
            else:
                terms = (ShiftTerm("flat", 0, 0.5), ShiftTerm("flat", o, 0.5))
            rounds.append(Topology(f"exp1peer[{o}]", n, terms))
        super().__init__(name=f"round_robin_exp({n})", n_agents=n,
                         rounds=tuple(rounds))


class AlternatingHierarchical(GossipSchedule):
    """``intra_every`` intra-pod rounds followed by one inter-pod round.

    Intra rounds mix only inside each pod (I_P ⊗ W_intra — pure ICI,
    zero DCI bytes); the closing inter round mixes the pod ring
    (W_ring(P) ⊗ I_D — the only DCI traffic of the period).  Every round is
    symmetric doubly stochastic PSD, so the product is doubly stochastic;
    connectivity over the period gives it a positive spectral gap.
    """

    def __init__(self, pods: int, per_pod: int, intra_every: int = 1,
                 intra: str = "ring"):
        assert pods >= 1 and per_pod >= 1 and intra_every >= 1
        n = pods * per_pod
        grid = (pods, per_pod)

        if per_pod == 1:
            intra_terms: Tuple[ShiftTerm, ...] = (ShiftTerm("flat", 0, 1.0),)
        elif intra == "full":
            intra_terms = tuple(ShiftTerm("intra", s, 1.0 / per_pod)
                                for s in range(per_pod))
        else:
            intra_terms = tuple(ShiftTerm("intra", t.shift, t.weight)
                                for t in ring(per_pod).terms)
        intra_round = Topology("alt_intra", n, intra_terms, grid=grid)

        if pods == 1:
            inter_terms: Tuple[ShiftTerm, ...] = (ShiftTerm("flat", 0, 1.0),)
        else:
            inter_terms = tuple(ShiftTerm("inter", t.shift, t.weight)
                                for t in ring(pods).terms)
        inter_round = Topology("alt_inter", n, inter_terms, grid=grid)

        super().__init__(name=f"alt_hier({pods}x{per_pod})", n_agents=n,
                         rounds=(intra_round,) * intra_every + (inter_round,))


# ---------------------------------------------------------------------------
# registry / config-level constructor
# ---------------------------------------------------------------------------

SCHEDULES = ("static", "round_robin", "alt_hier")


def make_schedule(name: str, n_agents: int, *, topo: Optional[Topology] = None,
                  pods: int = 1, period: int = 0,
                  seed: int = 0) -> GossipSchedule:
    """Config-level schedule constructor (``RunConfig.gossip_schedule``).

    ``static`` wraps ``topo`` (falls back to the static exp graph);
    ``round_robin`` builds :class:`RoundRobinExp` (``seed`` != 0 shuffles the
    offset order); ``alt_hier`` builds :class:`AlternatingHierarchical` with
    ``period`` intra rounds per inter round (0 → 1).
    """
    if name in ("static", "", None):
        return StaticSchedule(topo if topo is not None else exp_graph(n_agents))
    if name == "round_robin":
        return RoundRobinExp(n_agents, seed=seed or None)
    if name == "alt_hier":
        assert pods >= 1 and n_agents % pods == 0, (n_agents, pods)
        return AlternatingHierarchical(pods, n_agents // pods,
                                       intra_every=period or 1)
    raise ValueError(f"unknown gossip schedule {name!r}; have {SCHEDULES}")


# ---------------------------------------------------------------------------
# wire-byte model (ppermute engine; DESIGN §4 table)
# ---------------------------------------------------------------------------

def term_wire_rows(topo: Topology, t: ShiftTerm,
                   agents_per_device: int = 1) -> int:
    """Agent-rows each device transmits for one gossip term under the
    ppermute engine.

    Unblocked (one agent per device) every nonzero-shift term ships the full
    one-agent payload.  Blocked (B agents per device) a flat roll by s
    decomposes as s = qB + r: the B−r rows bound for device d−q plus the r
    boundary rows bound for d−q−1, with whichever part is device-local
    (q ≡ 0 or q+1 ≡ 0 mod ring) costing nothing — so sub-block shifts
    (|s| < B, e.g. the ring's ±1) ship only the r boundary rows.  Intra
    terms that fit whole pods on a device are free.
    """
    if t.shift == 0 or topo.n_agents == 1:
        return 0
    B = agents_per_device
    if B == 1:
        return 1
    P, D = topo.grid_shape()
    A = topo.n_agents
    assert A % B == 0, (A, B)
    if t.level == "intra":
        if B % D == 0:          # whole pods per device: local roll
            return 0
        assert D % B == 0, (D, B)
        n_ring, shift = D // B, t.shift % D
    elif t.level == "inter":
        n_ring, shift = A // B, (t.shift * D) % A
    else:
        n_ring, shift = A // B, t.shift % A
    q, r = divmod(shift, B)
    rows = 0
    if q % n_ring:
        rows += B - r
    if r and (q + 1) % n_ring:
        rows += r
    return rows


def wire_bytes_per_step(sched: GossipSchedule, step: int, *,
                        elems_per_agent: int, itemsize: int = 4,
                        agents_per_device: int = 1,
                        engine: str = "ppermute", codec=None) -> int:
    """Total bytes on the wire (summed over devices) for one gossip
    application at ``step``.

    Model: ``ppermute`` counts the rows each device actually ships
    (:func:`term_wire_rows`); ``shifts`` lowers every nonzero roll to a
    full-payload collective-permute (GSPMD; equals ppermute at B = 1);
    ``dense`` needs every remote row — an all-gather.

    ``codec`` (a :class:`repro.core.wire.WireCodec`, DESIGN §9) derives the
    per-agent payload bytes from the wire dtype plus the int8 per-block
    scale sidecar instead of the uncompressed ``elems_per_agent ×
    itemsize``; the engines permute the encoded components through the same
    row plan, so the row counts are unchanged — only the bytes-per-row
    factor shrinks.
    """
    topo = sched.round(step)
    A = topo.n_agents
    B = agents_per_device
    n_dev = A // B
    bytes_per_agent = (codec.payload_bytes(elems_per_agent)
                       if codec is not None else elems_per_agent * itemsize)
    wire_rows = getattr(topo, "wire_rows", None)
    if wire_rows is not None:
        # liveness-masked rounds (core.elastic.MaskedTopology) carry their
        # own per-agent source maps and account for themselves
        return wire_rows(B, engine) * bytes_per_agent
    if engine == "dense":
        rows = (A - B) * n_dev          # every device gathers all remote rows
    elif engine == "shifts":
        rows = sum(1 for t in topo.terms if t.shift != 0) * A
    else:
        rows = sum(term_wire_rows(topo, t, B) for t in topo.terms) * n_dev
    return rows * bytes_per_agent


def group_wire_bytes_per_step(groups, scheds, step: int, *,
                              itemsize: int = 4, agents_per_device: int = 1,
                              engine: str = "ppermute",
                              codecs=None) -> dict:
    """Per-group wire-byte model for a policy-group bus (DESIGN §12).

    ``groups`` is an iterable of :class:`repro.core.bus.BusGroup` (anything
    with ``name``/``elems``/``gossip_every``); ``scheds`` maps group name →
    :class:`GossipSchedule` (opt-out groups need no entry); ``codecs``
    optionally maps group name → :class:`repro.core.wire.WireCodec`.

    A group ships bytes only on *its* gossip steps: ``gossip_every == 0``
    never (full opt-out — zero wire bytes, matching the group mixer's
    zero-permute HLO), ``k >= 1`` on steps with ``step % k == k-1``, and
    then the group's round clock is ``step // k``
    (:func:`repro.train.trainer.gossip_round_step` — no gcd aliasing
    between the skip cadence and the schedule period).  Returns
    ``{name: bytes, ..., "total": bytes}``.
    """
    out = {}
    total = 0
    for g in groups:
        k = g.gossip_every
        if k == 0 or g.rows == 0 or (k > 1 and step % k != k - 1):
            out[g.name] = 0
            continue
        gstep = step // k if k > 1 else step
        codec = (codecs or {}).get(g.name)
        b = wire_bytes_per_step(
            scheds[g.name], gstep, elems_per_agent=g.elems,
            itemsize=itemsize, agents_per_device=agents_per_device,
            engine=engine, codec=codec)
        out[g.name] = b
        total += b
    out["total"] = total
    return out
