"""repro.core — the paper's contribution: EDM and the decentralized substrate."""
from .topology import (  # noqa: F401
    Topology, ShiftTerm, ring, exp_graph, torus2d, fully_connected,
    hierarchical, disconnected, spectral_stats,
)
from .mixing import mix_dense, mix_shifts, mix_ppermute, make_mixer  # noqa: F401
from .optimizers import DecOptimizer, make_optimizer, ALGORITHMS  # noqa: F401
from . import metrics  # noqa: F401
