"""repro.core — the paper's contribution: EDM and the decentralized substrate."""
from .topology import (  # noqa: F401
    Topology, ShiftTerm, ring, exp_graph, torus2d, fully_connected,
    hierarchical, disconnected, spectral_stats, matrix_lam,
)
from .mixing import (  # noqa: F401
    mix_dense, mix_shifts, mix_ppermute, mix_dense_sharded, make_mixer,
    make_schedule_mixer, make_overlap_mixer, build_mixer, GroupPlan,
    make_group_mixer, accumulate_f32,
)
from .schedule import (  # noqa: F401
    GossipSchedule, StaticSchedule, RoundRobinExp, AlternatingHierarchical,
    make_schedule, wire_bytes_per_step, group_wire_bytes_per_step,
)
from .elastic import (  # noqa: F401
    LivenessMask, MaskedTopology, degrade_round, DropPlan, ElasticSchedule,
    StragglerPlan,
)
from .optimizers import (  # noqa: F401
    DecOptimizer, make_optimizer, make_edm_bus, make_edm_bus_ef, ALGORITHMS,
)
from .wire import (  # noqa: F401
    WIRE_FORMATS, WireCodec, make_codec, encode_ef,
)
from .bus import (  # noqa: F401
    BusLayout, LeafSlot, GroupSpec, BusGroup, make_layout, layout_of,
    group_specs_from_json, leaf_paths, pack_tree, unpack_tree,
    leaf_views, make_pipeline, pipeline_payload, pipeline_advance,
)
from . import metrics  # noqa: F401
