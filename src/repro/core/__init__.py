"""repro.core — the paper's contribution: EDM and the decentralized substrate."""
from .topology import (  # noqa: F401
    Topology, ShiftTerm, ring, exp_graph, torus2d, fully_connected,
    hierarchical, disconnected, spectral_stats, matrix_lam,
)
from .mixing import (  # noqa: F401
    mix_dense, mix_shifts, mix_ppermute, make_mixer, make_schedule_mixer,
    accumulate_f32,
)
from .schedule import (  # noqa: F401
    GossipSchedule, StaticSchedule, RoundRobinExp, AlternatingHierarchical,
    make_schedule, wire_bytes_per_step,
)
from .optimizers import DecOptimizer, make_optimizer, ALGORITHMS  # noqa: F401
from . import metrics  # noqa: F401
