"""Elastic fault-tolerant gossip: liveness masks, drop plans, stragglers.

Everything before this module assumes a fixed, healthy agent set; the
paper's Assumption 1 (doubly stochastic W with positive diagonal) is
exactly what a real fleet loses when an agent drops and its row of W(t)
silently stops summing to one.  This module makes the Assumption-1
contract survive churn (DESIGN §8):

* :class:`LivenessMask` — one alive/dead bit per agent.
* :func:`degrade_round` — rewrite one gossip round's :class:`Topology`
  under a mask via **survivor-rank rewiring**: each circulant term with
  linearized global shift ``s`` becomes, on the ``m`` survivors ordered by
  global index, the rank-space rotation by ``s mod m`` (dead agents map to
  themselves).  Every degraded term is therefore a permutation of the
  survivors ⊕ identity on the dead — so the degraded round is doubly
  stochastic *by construction* for arbitrary base rounds (including the
  asymmetric one-peer rounds), keeps a positive diagonal, and the survivor
  block stays circulant: any base round carrying a ±1 shift keeps the
  survivor ring connected, so the degraded period product contracts
  whenever ≥ 2 agents stay alive the whole period.  Terms whose survivor
  shift collapses to 0 (mod m) fold into the self term, so no degenerate
  identity permute ever reaches the wire.
* :class:`DropPlan` — a deterministic step-indexed sequence of liveness
  epochs (churn fault-injection; JSON round-trippable for ``--churn``).
* :class:`ElasticSchedule` — a :class:`GossipSchedule` whose round list is
  the base schedule's rounds degraded per epoch; ``round_index`` maps the
  global step to (epoch, base round) and ``check_assumption1`` asserts the
  per-epoch Assumption-1 transfer: every degraded round doubly stochastic,
  nonnegative, positive diagonal, dead rows/cols exactly identity, and the
  epoch's survivor-block period product contracting.
* :class:`StragglerPlan` — a step-indexed set of LATE gossip terms for the
  overlap pipeline: a late payload slot degrades its term to self-weight
  instead of blocking (``make_overlap_mixer``'s ``complete(..., late=)``).

Dead agents freeze: their x/m/ψ rows ride along under weight-1 self terms,
untouched by any degraded round, so re-admission is a pure checkpoint
resize (:func:`repro.train.checkpoint.resize_state`).
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import json
import os
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from .schedule import GossipSchedule
from .topology import ShiftTerm, Topology, matrix_lam

__all__ = [
    "LivenessMask", "MaskedTopology", "degrade_round", "DropPlan",
    "ElasticSchedule", "StragglerPlan",
]


@dataclasses.dataclass(frozen=True)
class LivenessMask:
    """One alive bit per agent.  ``survivors`` are ordered by global index;
    ``rank`` is each survivor's position on the degraded survivor ring —
    the coordinate :func:`degrade_round`'s rewiring rotates."""

    alive: Tuple[bool, ...]

    @classmethod
    def of(cls, alive: Iterable) -> "LivenessMask":
        return cls(tuple(bool(a) for a in alive))

    @property
    def n(self) -> int:
        return len(self.alive)

    @property
    def m(self) -> int:
        return sum(self.alive)

    @property
    def survivors(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.alive, dtype=bool))

    def rank(self) -> np.ndarray:
        """Survivor rank per agent (-1 for dead)."""
        r = np.full(self.n, -1, dtype=np.int64)
        r[self.survivors] = np.arange(self.m)
        return r


@dataclasses.dataclass(frozen=True)
class MaskedTopology(Topology):
    """A degraded gossip round: explicit per-term source maps + per-agent
    weight columns instead of pure circulant shifts.

    ``terms[k]`` is a synthetic ``ShiftTerm("masked", sigma_k, w_k)`` whose
    ``shift`` is the survivor-rank rotation (0 = the self term) and whose
    ``weight`` is the survivor weight; ``sources[k][i]`` / ``weights[k][i]``
    carry the full per-agent map (dead agents: source = self, weight = 1 on
    the self term, 0 elsewhere).  ``term_sources`` is overridden, so the
    dense oracle, the shifts engine's gather fallback and the ppermute
    engine's explicit source→target permute lists all derive from the same
    map — masking cannot drift between engines.
    """

    sources: Tuple[Tuple[int, ...], ...] = ()
    weights: Tuple[Tuple[float, ...], ...] = ()
    alive: Tuple[bool, ...] = ()

    def _term_index(self, t: ShiftTerm) -> int:
        # degraded terms are deduped by survivor shift, so index by it
        for k, tk in enumerate(self.terms):
            if tk.shift == t.shift:
                return k
        raise KeyError(t)

    def term_sources(self, t: ShiftTerm) -> np.ndarray:
        return np.asarray(self.sources[self._term_index(t)], dtype=np.int64)

    def term_weights(self, t: ShiftTerm) -> np.ndarray:
        """Per-agent weight column of term ``t`` (dead agents carry their
        frozen self weight here — the engines apply it agent-pointwise)."""
        return np.asarray(self.weights[self._term_index(t)],
                          dtype=np.float64)

    def dense_matrix(self) -> np.ndarray:
        n = self.n_agents
        W = np.zeros((n, n), dtype=np.float64)
        idx = np.arange(n)
        for src, w in zip(self.sources, self.weights):
            W[idx, np.asarray(src)] += np.asarray(w)
        return W

    def lam(self) -> float:
        # degraded rounds are asymmetric in general — eigvalsh is wrong
        return matrix_lam(self.dense_matrix())

    def wire_rows(self, agents_per_device: int = 1,
                  engine: str = "ppermute") -> int:
        """Total agent-rows on the wire for one application (all devices).

        B = 1 ppermute ships one row per agent whose source isn't itself
        (one collective-permute per nonzero survivor shift); the blocked
        masked path (B > 1) falls back to an agent-axis all-gather
        (DESIGN §8 fallback matrix), as does the dense engine."""
        A = self.n_agents
        B = agents_per_device
        if engine == "dense" or (engine == "ppermute" and B > 1):
            return (A - B) * (A // B)
        idx = np.arange(A)
        return sum(int(np.sum(np.asarray(src) != idx)) for src in self.sources)


def _linear_shift(t: ShiftTerm, grid_shape: Tuple[int, int]) -> int:
    """A term's shift linearized onto the flat agent index: flat/intra
    shifts move by ``shift`` consecutive agents, inter shifts by whole
    pods (``shift * D``)."""
    P, D = grid_shape
    if t.level in ("flat", "intra"):
        return t.shift
    if t.level == "inter":
        return t.shift * D
    raise ValueError(t.level)


def degrade_round(topo: Topology, alive) -> Topology:
    """Rewrite one gossip round for the given liveness mask.

    Survivor-rank rewiring: a term with linearized shift ``s`` maps alive
    agent ``i`` to the survivor ``s`` ranks behind it on the survivor ring
    (``sigma = s mod m``); dead agents map to themselves.  Terms sharing a
    survivor shift merge (their weights add), and ``sigma = 0`` terms fold
    into the self term — so every emitted nonzero term is one genuine
    permutation of the survivors, never an identity permute.

    Returns ``topo`` unchanged (same object) when every agent is alive, so
    the healthy path stays bit-identical to the un-masked engines.
    """
    mask = alive if isinstance(alive, LivenessMask) else LivenessMask.of(alive)
    n = topo.n_agents
    assert mask.n == n, (mask.n, n)
    m = mask.m
    assert m >= 1, "degrade_round needs at least one alive agent"
    if m == n:
        return topo
    surv = mask.survivors
    rank = mask.rank()
    gs = topo.grid_shape()
    dead = np.flatnonzero(~np.asarray(mask.alive, dtype=bool))

    # merge base terms by survivor shift (weights add; sigma=0 is the self)
    sigma_w: Dict[int, float] = {}
    order: list = []
    for t in topo.terms:
        sigma = _linear_shift(t, gs) % m
        if sigma not in sigma_w:
            sigma_w[sigma] = 0.0
            order.append(sigma)
        sigma_w[sigma] += t.weight
    assert 0 in sigma_w and sigma_w[0] > 0, \
        f"{topo.name}: round has no positive self weight to degrade onto"

    terms, sources, weights = [], [], []
    for sigma in order:
        w = sigma_w[sigma]
        src = np.arange(n)
        src[surv] = surv[(rank[surv] - sigma) % m]
        wcol = np.zeros(n)
        wcol[surv] = w
        wcol[dead] = 1.0 if sigma == 0 else 0.0
        terms.append(ShiftTerm("masked", int(sigma), float(w)))
        sources.append(tuple(int(s) for s in src))
        weights.append(tuple(float(x) for x in wcol))
    return MaskedTopology(
        name=f"masked({topo.name},m={m})", n_agents=n, terms=tuple(terms),
        grid=None, sources=tuple(sources), weights=tuple(weights),
        alive=tuple(mask.alive))


# ---------------------------------------------------------------------------
# deterministic churn plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DropPlan:
    """A deterministic step-indexed liveness plan: a sorted sequence of
    epochs ``(start_step, alive mask)``; the mask of the last epoch whose
    start is ≤ step applies.  The first epoch must start at 0.

    JSON wire format (``--churn``; path, inline string or dict)::

        {"n_agents": 8,
         "epochs": [{"start": 0, "down": []},
                    {"start": 8, "down": [3, 5]}]}

    (``"alive": [...]`` is accepted in place of ``"down"``.)
    """

    n_agents: int
    epochs: Tuple[Tuple[int, Tuple[bool, ...]], ...]

    def __post_init__(self):
        assert self.epochs, "DropPlan needs at least one epoch"
        starts = [s for s, _ in self.epochs]
        assert starts[0] == 0, f"first epoch must start at step 0: {starts}"
        assert all(a < b for a, b in zip(starts, starts[1:])), \
            f"epoch starts must be strictly increasing: {starts}"
        for s, alive in self.epochs:
            assert len(alive) == self.n_agents, (s, len(alive), self.n_agents)
            assert any(alive), f"epoch @{s} leaves no agent alive"

    @property
    def n_epochs(self) -> int:
        return len(self.epochs)

    @property
    def starts(self) -> Tuple[int, ...]:
        return tuple(s for s, _ in self.epochs)

    def epoch_index(self, step):
        """Epoch containing ``step`` — Python int for concrete steps,
        traced int32 (searchsorted) for traced ones."""
        if isinstance(step, (int, np.integer)):
            return bisect.bisect_right(self.starts, int(step)) - 1
        import jax.numpy as jnp
        starts = jnp.asarray(self.starts, jnp.int32)
        return jnp.searchsorted(starts, jnp.asarray(step, jnp.int32),
                                side="right") - 1

    def alive_at(self, step: int) -> np.ndarray:
        return np.asarray(self.epochs[self.epoch_index(int(step))][1],
                          dtype=bool)

    def always_alive(self) -> np.ndarray:
        """Agents alive in every epoch — the set the divergence gates
        evaluate (dead agents freeze, which is correct but not progress)."""
        acc = np.ones(self.n_agents, dtype=bool)
        for _, alive in self.epochs:
            acc &= np.asarray(alive, dtype=bool)
        return np.flatnonzero(acc)

    # ---- construction / serialization -----------------------------------
    @classmethod
    def from_events(cls, n_agents: int,
                    events: Sequence[Tuple[int, Iterable[int]]]) -> "DropPlan":
        """``events`` = [(start_step, down_agent_ids), ...]."""
        epochs = []
        for start, down in events:
            alive = np.ones(n_agents, dtype=bool)
            alive[list(down)] = False
            epochs.append((int(start), tuple(bool(a) for a in alive)))
        return cls(n_agents, tuple(epochs))

    @classmethod
    def from_json(cls, spec: Any) -> "DropPlan":
        """Accepts a dict, an inline JSON string, or a path to a file."""
        if isinstance(spec, str):
            spec = (json.loads(spec) if spec.lstrip().startswith("{")
                    else json.load(open(spec)))
        n = int(spec["n_agents"])
        epochs = []
        for e in spec["epochs"]:
            if "alive" in e:
                alive = tuple(bool(a) for a in e["alive"])
            else:
                mask = np.ones(n, dtype=bool)
                mask[list(e.get("down", []))] = False
                alive = tuple(bool(a) for a in mask)
            epochs.append((int(e["start"]), alive))
        return cls(n, tuple(epochs))

    def to_json(self) -> dict:
        return {"n_agents": self.n_agents,
                "epochs": [{"start": s,
                            "down": [int(i) for i in
                                     np.flatnonzero(~np.asarray(a, bool))]}
                           for s, a in self.epochs]}

    @classmethod
    def random(cls, n_agents: int, drop_rate: float, *, seed: int = 0,
               n_epochs: int = 4, epoch_len: int = 8,
               min_alive: int = 2) -> "DropPlan":
        """Deterministic random churn: each epoch drops each non-anchor
        agent independently with probability ``drop_rate``; the first
        ``min_alive`` agents are anchors (never dropped), so at least
        ``min_alive`` agents stay alive the whole plan and the period
        product keeps a contracting survivor block."""
        assert 0.0 <= drop_rate < 1.0, drop_rate
        assert 1 <= min_alive <= n_agents, (min_alive, n_agents)
        rng = np.random.default_rng(seed)
        epochs = []
        for e in range(n_epochs):
            alive = np.ones(n_agents, dtype=bool)
            if drop_rate > 0.0:
                roll = rng.random(n_agents) < drop_rate
                roll[:min_alive] = False
                alive &= ~roll
            epochs.append((e * epoch_len, tuple(bool(a) for a in alive)))
        return cls(n_agents, tuple(epochs))


# ---------------------------------------------------------------------------
# liveness-masked schedule
# ---------------------------------------------------------------------------

class ElasticSchedule(GossipSchedule):
    """A base :class:`GossipSchedule` degraded per :class:`DropPlan` epoch.

    ``rounds`` flattens to (epoch × base round): round index of global step
    t is ``epoch_index(t) · base.period + t % base.period``.  Epoch starts
    must be multiples of the base period, so the liveness mask is constant
    across every period — each degraded round is then block diagonal
    (survivor mixing ⊕ identity on the dead) and Assumption 1 transfers
    per epoch: the period product restricted to that epoch's survivors is
    doubly stochastic with spectral gap > 0 whenever ≥ 2 agents survive.
    """

    def __init__(self, base: GossipSchedule, plan: DropPlan):
        assert plan.n_agents == base.n_agents, \
            (plan.n_agents, base.n_agents)
        p = base.period
        for start, _ in plan.epochs:
            assert start % p == 0, \
                f"epoch start {start} must align to the base period {p} " \
                f"(the liveness mask must be constant across each period)"
        rounds = tuple(degrade_round(r, alive)
                       for _, alive in plan.epochs for r in base.rounds)
        super().__init__(name=f"elastic({base.name})",
                         n_agents=base.n_agents, rounds=rounds)
        object.__setattr__(self, "base", base)
        object.__setattr__(self, "plan", plan)

    def round_index(self, step):
        p = self.base.period
        return self.plan.epoch_index(step) * p + step % p

    def round(self, step: int) -> Topology:
        return self.rounds[int(self.round_index(int(step)))]

    # ---- per-epoch Assumption-1 transfer ---------------------------------
    def epoch_rounds(self, e: int) -> Tuple[Topology, ...]:
        p = self.base.period
        return self.rounds[e * p:(e + 1) * p]

    def epoch_product(self, e: int) -> np.ndarray:
        W = np.eye(self.n_agents)
        for topo in self.epoch_rounds(e):
            W = topo.dense_matrix() @ W
        return W

    def epoch_stats(self) -> list:
        """Per-epoch survivor-block spectral stats (the degraded λ with
        which EDM's bounds transfer for that epoch)."""
        out = []
        for e, (start, alive) in enumerate(self.plan.epochs):
            surv = np.flatnonzero(np.asarray(alive, bool))
            sub = self.epoch_product(e)[np.ix_(surv, surv)]
            lam = matrix_lam(sub) if len(surv) > 1 else 0.0
            out.append({"epoch": e, "start": start, "alive": len(surv),
                        "lambda": lam, "gap": 1.0 - lam})
        return out

    def product_spectral_stats(self) -> dict:
        stats = self.epoch_stats()
        return {
            "name": self.name,
            "n": self.n_agents,
            "period": self.base.period,
            "epochs": self.plan.n_epochs,
            "lambda": max(s["lambda"] for s in stats),
            "gap": min(s["gap"] for s in stats),
            "permutes_per_step": max(
                sum(1 for t in r.terms if t.shift != 0) for r in self.rounds),
        }

    def check_assumption1(self, atol: float = 1e-10) -> None:
        """Assumption-1 transfer under churn (DESIGN §8): every degraded
        round is doubly stochastic, nonnegative, positive diagonal, and
        exactly identity on its dead rows/columns; each epoch's period
        product restricted to the epoch's survivors is doubly stochastic
        with spectral gap > 0 whenever ≥ 2 agents survive it."""
        n = self.n_agents
        ones = np.ones(n)
        for e, (start, alive) in enumerate(self.plan.epochs):
            surv = np.flatnonzero(np.asarray(alive, bool))
            dead = np.flatnonzero(~np.asarray(alive, bool))
            m = len(surv)
            for r, topo in enumerate(self.epoch_rounds(e)):
                W = topo.dense_matrix()
                tag = f"{self.name} epoch {e} round {r}"
                assert np.allclose(W @ ones, ones, atol=atol), \
                    f"{tag}: W 1 != 1"
                assert np.allclose(ones @ W, ones, atol=atol), \
                    f"{tag}: 1ᵀ W != 1ᵀ"
                assert np.all(W >= -atol), f"{tag}: negative w_ij"
                assert np.all(np.diag(W) > 0), f"{tag}: w_ii = 0"
                if len(dead):
                    eye = np.eye(n)
                    assert np.array_equal(W[dead], eye[dead]), \
                        f"{tag}: dead rows not identity"
                    assert np.array_equal(W[:, dead], eye[:, dead]), \
                        f"{tag}: dead columns not identity"
            if m >= 2:
                sub = self.epoch_product(e)[np.ix_(surv, surv)]
                mo = np.ones(m)
                assert np.allclose(sub @ mo, mo, atol=atol), \
                    f"{self.name} epoch {e}: survivor product not row-stochastic"
                assert np.allclose(mo @ sub, mo, atol=atol), \
                    f"{self.name} epoch {e}: survivor product not col-stochastic"
                gap = 1.0 - matrix_lam(sub)
                assert gap > atol, \
                    f"{self.name} epoch {e}: survivor product not " \
                    f"contracting (gap={gap})"


# ---------------------------------------------------------------------------
# straggler plans (overlap pipeline, DESIGN §8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StragglerPlan:
    """Step-indexed LATE gossip terms for the overlapped pipeline.

    ``late[(step, (k, ...))]`` marks payload-stack slots ``k`` late at
    ``step``: the combine substitutes each late slot's payload with the
    round's self payload under the slot's original weight — exactly the
    self-weight absorption ``W + Σ_late w_k (I − P_k)``, which preserves
    double stochasticity and never multiplies the late (possibly garbage)
    buffer, so a straggler degrades mixing instead of blocking or NaNing
    the step.  ``n_terms`` must equal the overlap mixer's padded stack
    arity K (``complete.n_terms``).
    """

    n_terms: int
    late: Tuple[Tuple[int, Tuple[int, ...]], ...] = ()

    def __post_init__(self):
        for step, ks in self.late:
            assert step >= 0, step
            assert all(0 <= k < self.n_terms for k in ks), (step, ks)

    @functools.cached_property
    def _table(self) -> np.ndarray:
        """(T+1, K) bool; row T (all-False) is the every-later-step row."""
        T = 1 + max((s for s, _ in self.late), default=-1)
        tab = np.zeros((T + 1, self.n_terms), dtype=bool)
        for step, ks in self.late:
            tab[step, list(ks)] = True
        return tab

    def late_at(self, step):
        """(K,) bool late mask for ``step`` (concrete or traced)."""
        import jax.numpy as jnp
        tab = jnp.asarray(self._table)
        idx = jnp.minimum(jnp.asarray(step, jnp.int32), tab.shape[0] - 1)
        return tab[idx]
