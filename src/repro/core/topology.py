"""Communication topologies (mixing matrices W) for decentralized optimization.

The paper (EDM) requires W symmetric, doubly stochastic, with positive
spectrum (Assumption 1).  We support two representations:

* ``dense_matrix(n)`` — the explicit (n, n) matrix, used by the simulation
  mixing engine and by all spectral-gap computations / tests.
* ``terms`` — a list of :class:`ShiftTerm` describing W as a weighted sum of
  axis rolls over the agent grid.  Circulant topologies (ring, exp, torus,
  hierarchical Kronecker combinations) admit this form, which is what lowers
  to ``collective-permute`` chains on a TPU mesh.

``lam(n)``  = second largest |eigenvalue| of W   (the paper's λ)
``1 - lam`` = spectral gap driving every bound in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "ShiftTerm",
    "Topology",
    "ring",
    "exp_graph",
    "torus2d",
    "fully_connected",
    "hierarchical",
    "disconnected",
    "spectral_stats",
    "matrix_lam",
]


def matrix_lam(W: np.ndarray) -> float:
    """Second largest eigenvalue *modulus* of a stochastic matrix.

    Unlike :meth:`Topology.lam` this does not assume symmetry — it is the λ
    of round products of time-varying schedules (``GossipSchedule.
    period_product``), which are asymmetric whenever any round is (the
    one-peer exp rounds are ½I + ½R, a rotation half).
    """
    if W.shape[0] <= 1:
        return 0.0
    ev = np.sort(np.abs(np.linalg.eigvals(W)))
    return float(ev[-2])


@dataclasses.dataclass(frozen=True)
class ShiftTerm:
    """One `weight * roll(x, shift)` term of a circulant-expressible W.

    level:
      "flat"  — roll over the flattened agent axis (all A agents in a ring)
      "intra" — roll within each pod (agent grid reshaped to (P, D), axis=1)
      "inter" — roll across pods  (axis=0 of the (P, D) grid)
    """

    level: str
    shift: int
    weight: float


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    n_agents: int
    terms: Tuple[ShiftTerm, ...]
    # (P, D) factorization of the agent axis for intra/inter terms; None for flat.
    grid: Optional[Tuple[int, int]] = None

    # ---- per-term permutation structure ---------------------------------
    def grid_shape(self) -> Tuple[int, int]:
        """(P, D) factorization of the agent axis (grid, or (1, n) for flat)."""
        if self.grid is None:
            return 1, self.n_agents
        P, D = self.grid
        assert P * D == self.n_agents, (P, D, self.n_agents)
        return P, D

    def term_sources(self, t: ShiftTerm) -> np.ndarray:
        """``src[i]`` = agent whose payload lands on agent ``i`` under term
        ``t`` (matches ``jnp.roll`` semantics: ``x_new[i] = x[(i-shift) % n]``).

        This single index map backs all three gossip engines: the dense
        oracle scatters ``t.weight`` at ``W[i, src[i]]``, and the ppermute
        engine turns it directly into a ``collective-permute``
        source→target list (DESIGN §3).
        """
        n = self.n_agents
        idx = np.arange(n)
        P, D = self.grid_shape()
        p_idx, d_idx = idx // D, idx % D
        if t.level == "flat":
            return (idx - t.shift) % n
        if t.level == "intra":
            return p_idx * D + (d_idx - t.shift) % D
        if t.level == "inter":
            return ((p_idx - t.shift) % P) * D + d_idx
        raise ValueError(t.level)

    # ---- dense form ------------------------------------------------------
    def dense_matrix(self) -> np.ndarray:
        n = self.n_agents
        W = np.zeros((n, n), dtype=np.float64)
        idx = np.arange(n)
        for t in self.terms:
            W[idx, self.term_sources(t)] += t.weight
        return W

    # ---- spectral properties --------------------------------------------
    def eigenvalues(self) -> np.ndarray:
        return np.linalg.eigvalsh(self.dense_matrix())

    def lam(self) -> float:
        """Second largest |eigenvalue| — the paper's λ."""
        ev = np.sort(np.abs(self.eigenvalues()))
        return float(ev[-2]) if self.n_agents > 1 else 0.0

    def spectral_gap(self) -> float:
        return 1.0 - self.lam()

    def min_eigenvalue(self) -> float:
        return float(self.eigenvalues().min())

    def check_assumption1(self, atol: float = 1e-10) -> None:
        """Validate the paper's Assumption 1 (symmetric, doubly stochastic,
        positive diagonal, PSD)."""
        W = self.dense_matrix()
        n = self.n_agents
        assert np.allclose(W, W.T, atol=atol), "W must be symmetric"
        assert np.allclose(W @ np.ones(n), np.ones(n), atol=atol), "W 1 = 1"
        assert np.all(np.diag(W) > 0), "w_ii > 0"
        assert self.min_eigenvalue() > -atol, "W must be PSD (Assumption 1(3))"

    def lazify(self) -> "Topology":
        """Return W~ = (W + I)/2 — the paper's Remark 1 transform guaranteeing
        a positive spectrum for any symmetric doubly-stochastic W."""
        new_terms = tuple(
            ShiftTerm(t.level, t.shift, t.weight * 0.5) for t in self.terms
        ) + (ShiftTerm("flat", 0, 0.5),)
        return Topology(f"lazy({self.name})", self.n_agents, new_terms, self.grid)


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def ring(n: int) -> Topology:
    """Paper's experimental topology: w_ii=1/2, w_{i,i±1}=1/4.

    Spectral gap 1-λ = Θ(1/n²); PSD by construction (eigs = (1+cos θ)/2 ≥ 0).
    """
    if n == 1:
        return Topology("ring", 1, (ShiftTerm("flat", 0, 1.0),))
    if n == 2:
        return Topology("ring", 2, (ShiftTerm("flat", 0, 0.5), ShiftTerm("flat", 1, 0.5)))
    terms = (
        ShiftTerm("flat", 0, 0.5),
        ShiftTerm("flat", 1, 0.25),
        ShiftTerm("flat", -1, 0.25),
    )
    return Topology("ring", n, terms)


def exp_graph(n: int) -> Topology:
    """Symmetric one-peer-per-power-of-two exponential graph.

    Connects i to i ± 2^j for j = 0..log2(n)-1, uniform weights.  Spectral
    gap 1-λ = Θ(1/log n) — the sparse topology with near-optimal gap; each
    step is O(log n) collective-permutes.
    """
    if n == 1:
        return Topology("exp", 1, (ShiftTerm("flat", 0, 1.0),))
    offsets = []
    j = 1
    while j <= n // 2:
        offsets.append(j)
        j *= 2
    uniq = []
    for o in offsets:
        uniq.append(o)
        if (n - o) % n != o:  # avoid duplicating the antipode
            uniq.append(-o)
    w = 1.0 / (len(uniq) + 1)
    terms = [ShiftTerm("flat", 0, w)] + [ShiftTerm("flat", o, w) for o in uniq]
    topo = Topology("exp", n, tuple(terms))
    # exp graphs are not PSD in general → lazify to satisfy Assumption 1(3)
    if topo.min_eigenvalue() < 0:
        topo = topo.lazify()
    return topo


def torus2d(p: int, d: int) -> Topology:
    """2-D torus over a (p, d) agent grid — matches the physical ICI torus.

    self 1/3, each of 4 neighbors 1/6.
    """
    n = p * d
    terms = [ShiftTerm("flat", 0, 1.0 / 3)]
    for lvl, size in (("inter", p), ("intra", d)):
        if size == 1:
            terms[0] = ShiftTerm("flat", 0, terms[0].weight + 1.0 / 3)
            continue
        if size == 2:
            terms.append(ShiftTerm(lvl, 1, 1.0 / 3))
        else:
            terms.append(ShiftTerm(lvl, 1, 1.0 / 6))
            terms.append(ShiftTerm(lvl, -1, 1.0 / 6))
    topo = Topology("torus2d", n, tuple(terms), grid=(p, d))
    if topo.min_eigenvalue() < 0:
        topo = topo.lazify()
    return topo


def fully_connected(n: int) -> Topology:
    """W = (1/n) 11ᵀ — gossip degenerates to exact averaging (all-reduce).

    Expressed as n flat shifts; used as the centralized-equivalent reference.
    """
    terms = tuple(ShiftTerm("flat", s, 1.0 / n) for s in range(n))
    return Topology("full", n, terms)


def hierarchical(pods: int, per_pod: int, c: float = 0.5,
                 intra: str = "full") -> Topology:
    """Bandwidth-aware multi-pod topology (our TPU adaptation, DESIGN §2):

        W = c · (I_P ⊗ W_intra)  +  (1-c) · (W_ring_pods ⊗ I_D)

    Convex combination of symmetric doubly-stochastic PSD matrices ⇒ satisfies
    Assumption 1.  Cross-pod traffic = one collective-permute; intra-pod
    mixing rides the fast ICI.
    """
    n = pods * per_pod
    terms: List[ShiftTerm] = []
    # intra-pod component (scaled by c)
    if per_pod == 1:
        terms.append(ShiftTerm("flat", 0, c))
    elif intra == "full":
        for s in range(per_pod):
            terms.append(ShiftTerm("intra", s, c / per_pod))
    else:  # intra ring
        rw = ring(per_pod)
        for t in rw.terms:
            terms.append(ShiftTerm("intra", t.shift, c * t.weight))
    # inter-pod ring component (scaled by 1-c)
    if pods == 1:
        terms.append(ShiftTerm("flat", 0, 1.0 - c))
    else:
        rp = ring(pods)
        for t in rp.terms:
            terms.append(ShiftTerm("inter", t.shift, (1.0 - c) * t.weight))
    return Topology("hier", n, tuple(terms), grid=(pods, per_pod))


def disconnected(n: int) -> Topology:
    """W = I — no communication (local SGD); for ablations."""
    return Topology("disconnected", n, (ShiftTerm("flat", 0, 1.0),))


def spectral_stats(topo: Topology) -> dict:
    ev = topo.eigenvalues()
    return {
        "name": topo.name,
        "n": topo.n_agents,
        "lambda": topo.lam(),
        "gap": topo.spectral_gap(),
        "min_eig": float(ev.min()),
    }
