"""Decentralized stochastic optimizers behind one functional API.

Every algorithm operates on pytrees with a **leading agent axis A** and a
gossip operator ``mix(tree) -> tree`` (see :mod:`repro.core.mixing`).  The
same code therefore runs the paper's n=32 ring simulation on one CPU device
and the 512-chip production mesh (agent axis sharded over ('pod','data')).

Implemented algorithms (paper §5 / Table 1 comparison set):

===========  ==================================================================
EDM          **the paper's contribution** — Exact-Diffusion with Momentum
ED/D²        Yuan et al. 2020 / Tang et al. 2018 (= EDM with β=0)
DSGD         Lian et al. 2017 plain decentralized SGD
DmSGD        Yu et al. 2019 decentralized momentum SGD
DSGT         Zhang & You 2019 stochastic gradient tracking
DSGT-HB      Gao et al. 2023 gradient tracking + heavy ball
DecentLaM    Yuan et al. 2021 large-batch decentralized momentum
QG-DmSGD     Lin et al. 2021 quasi-global momentum
===========  ==================================================================

API::

    opt = make_optimizer("edm", alpha=0.05, beta=0.9, mix=make_mixer(topo))
    state  = opt.init(params)                  # params leaves: (A, ...)
    params, state = opt.step(params, grads, state)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Params = Any
Grads = Any
State = Dict[str, Any]
Mixer = Callable[[Any], Any]

__all__ = ["DecOptimizer", "make_optimizer", "make_edm_bus",
           "make_edm_bus_ef", "ALGORITHMS"]


@dataclasses.dataclass(frozen=True)
class DecOptimizer:
    name: str
    init: Callable[[Params], State]
    step: Callable[[Params, Grads, State], tuple]


def _zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def _axpy(a, x, y):  # a*x + y, leafwise
    return jax.tree.map(lambda xi, yi: a * xi + yi, x, y)


def _lincomb(*pairs):
    """sum(c_k * tree_k) leafwise."""
    coeffs = [c for c, _ in pairs]
    trees = [t for _, t in pairs]

    def f(*leaves):
        out = coeffs[0] * leaves[0]
        for c, l in zip(coeffs[1:], leaves[1:]):
            out = out + c * l
        return out

    return jax.tree.map(f, *trees)


# ---------------------------------------------------------------------------
# EDM — the paper's Algorithm 1
# ---------------------------------------------------------------------------

def make_edm(alpha: float, beta: float, mix: Mixer,
             use_fused_kernel: bool = False) -> DecOptimizer:
    """Exact-Diffusion with Momentum (paper Algorithm 1).

    Per agent i:
        m   ← β m + (1-β) g
        ψ'  ← x − α m                   (adapt)
        φ   ← ψ' + x − ψ                (correct: the ED/D² bias correction)
        x   ← Σ_j w_ij φ_j              (combine: gossip)
    State: {m, psi}, ψ(0) = x(0) so that step 0 reduces to x ← W(x − α m).
    With β = 0 this is exactly ED/D².

    ``use_fused_kernel=True`` routes the elementwise chain through the Pallas
    ``edm_update`` kernel (kernels/edm_update.py) — TPU target; on CPU the
    kernel runs in interpret mode (tests) and the jnp chain is the default.
    """

    def init(params: Params) -> State:
        return {"m": _zeros_like(params), "psi": jax.tree.map(jnp.asarray, params)}

    def step(params: Params, grads: Grads, state: State):
        if use_fused_kernel:
            from repro.kernels import ops as kops
            m_new, phi, psi_new = kops.edm_update_tree(
                params, grads, state["m"], state["psi"], alpha=alpha, beta=beta)
        else:
            m_new = _lincomb((beta, state["m"]), ((1.0 - beta), grads))
            psi_new = _lincomb((1.0, params), (-alpha, m_new))
            # φ = ψ_new + x − ψ_prev
            phi = _lincomb((1.0, psi_new), (1.0, params), (-1.0, state["psi"]))
        new_params = mix(phi)
        return new_params, {"m": m_new, "psi": psi_new}

    return DecOptimizer("edm", init, step)


def make_edm_bus(alpha: float, beta: float, mix: Mixer, *,
                 block_rows: int | None = None,
                 use_fused_kernel: bool = False,
                 update=None) -> DecOptimizer:
    """Bus-resident EDM (DESIGN §5): same Algorithm 1 recursion as
    :func:`make_edm`, but every state tensor is ONE packed ``(A, rows, 128)``
    superbuffer (:mod:`repro.core.bus`) instead of a pytree of leaves.

    The whole step is then launch-minimal: one fused ``edm_update``
    pallas_call over the entire bus (``use_fused_kernel=True``; the unfused
    path is one XLA elementwise fusion), and — because the mixing engines
    treat the bus as a one-leaf tree — one ``ppermute`` per gossip term and
    one n-ary combine for the gossip, vs per-leaf launches everywhere in the
    tree-resident path.  ``init``/``step`` consume and produce bus buffers;
    packing/unpacking is the caller's job (``train/trainer.py`` packs once
    at ``init_state`` and unpacks only for loss/grad and checkpointing).

    Zero-preservation keeps the layout's pad region inert: m, ψ and φ are 0
    wherever x, g and ψ start 0, and every doubly-stochastic W maps 0 → 0,
    so pad bytes never leak into logical values.

    ``update`` overrides the fused-update call with a caller-built
    ``update(x, g, m, psi) -> (m', ψ', φ)`` — the shard-resident hook
    (DESIGN §7): the trainer wraps ``edm_update_bus`` in a ``shard_map``
    over the bus sharding so each FSDP shard launches the kernel on its
    own row block instead of XLA gathering the bus around an unpartitioned
    pallas_call.
    """

    def init(x_bus) -> State:
        # ψ(0) = x(0) as a *distinct* buffer: the donated train step aliases
        # params and psi independently, so they must not share storage.
        return {"m": jnp.zeros_like(x_bus), "psi": jnp.copy(x_bus)}

    def step(x_bus, g_bus, state: State):
        if update is not None:
            m_new, psi_new, phi = update(x_bus, g_bus, state["m"],
                                         state["psi"])
        elif use_fused_kernel:
            from repro.kernels import ops as kops
            m_new, psi_new, phi = kops.edm_update_bus(
                x_bus, g_bus, state["m"], state["psi"],
                alpha=alpha, beta=beta, block_rows=block_rows)
        else:
            m_new = beta * state["m"] + (1.0 - beta) * g_bus
            psi_new = x_bus - alpha * m_new
            phi = psi_new + x_bus - state["psi"]
        return mix(phi), {"m": m_new, "psi": psi_new}

    return DecOptimizer("edm_bus", init, step)


def make_edm_bus_ef(alpha: float, beta: float, mix: Mixer, codec, *,
                    block_rows: int | None = None,
                    use_fused_kernel: bool = False,
                    update=None,
                    error_feedback: bool = True) -> DecOptimizer:
    """Bus-resident EDM with an **error-feedback-compressed wire**
    (DESIGN §9): the bus analogue of :func:`make_edm_ef`, with the
    quantize + residual-update fused into the EDM Pallas pass
    (:func:`repro.kernels.ops.edm_update_bus_ef`) and the decode folded
    into the mixer's combine.  Per step::

        m'  = β m + (1-β) g
        ψ'  = x − α m'
        c   = (ψ' + x − ψ) + e          (φ plus the carried residual)
        pay = encode(c)                 (the wire payload — codec format)
        e'  = c − decode(pay)           (sender-local, cross-round carry)
        x'  = mix(pay)                  (wire-coded engine → f32 mix)

    ``mix`` must accept the codec's *encoded* payload and return the f32
    mixed bus (``make_mixer(..., wire=codec)``).  State is
    ``{m, psi, e}`` — the residual is a bus-shaped f32 buffer, so it rides
    the existing bus checkpoint/resize machinery unchanged.

    ``update`` overrides the fused call with a caller-built
    ``update(x, g, m, psi, e) -> (m', ψ', payload, e')`` — the
    shard-resident hook, mirroring :func:`make_edm_bus`.

    ``error_feedback=False`` drops the residual (``pay = encode(φ)``,
    ``e' = e = 0``): the *naive quantization* negative control the §E.1/E.2
    divergence gates use to document the floor blowup EF prevents.  Not a
    production mode.
    """

    def init(x_bus) -> State:
        return {"m": jnp.zeros_like(x_bus), "psi": jnp.copy(x_bus),
                "e": jnp.zeros_like(x_bus)}

    def step(x_bus, g_bus, state: State):
        if update is not None:
            assert error_feedback
            m_new, psi_new, payload, e_new = update(
                x_bus, g_bus, state["m"], state["psi"], state["e"])
        elif use_fused_kernel and error_feedback and codec.fmt != "f32":
            from repro.kernels import ops as kops
            m_new, psi_new, payload, e_new = kops.edm_update_bus_ef(
                x_bus, g_bus, state["m"], state["psi"], state["e"],
                alpha=alpha, beta=beta, fmt=codec.fmt,
                block_rows=codec.block_rows)
        else:
            from repro.core.wire import encode_ef
            m_new = beta * state["m"] + (1.0 - beta) * g_bus
            psi_new = x_bus - alpha * m_new
            phi = psi_new + x_bus - state["psi"]
            if error_feedback:
                payload, e_new = encode_ef(codec, phi + state["e"])
            else:
                payload, e_new = codec.encode(phi), state["e"]
        return mix(payload), {"m": m_new, "psi": psi_new, "e": e_new}

    return DecOptimizer("edm_bus_ef", init, step)


def make_ed(alpha: float, mix: Mixer, **_) -> DecOptimizer:
    """ED/D² — momentum-free exact diffusion (EDM with β=0)."""
    opt = make_edm(alpha, 0.0, mix)
    return DecOptimizer("ed", opt.init, opt.step)


def make_edm_ef(alpha: float, beta: float, mix: Mixer,
                compress_dtype: str = "bfloat16", **_) -> DecOptimizer:
    """EDM with error-feedback-compressed gossip (beyond-paper).

    Naive low-precision gossip payloads inflate EDM's floor ~200×
    (benchmarks/ablations.py): the correction φ = ψ' + x − ψ is a small
    difference of large iterates, so rounding it injects a *persistent* bias
    amplified by (1−λ)⁻¹.  Classic error feedback fixes this: each agent
    sends Q(φ + e) and keeps the quantization residual e locally —

        c   = φ + e
        φ̃  = Q(c)              (bf16 round-trip: the wire payload)
        e'  = c − φ̃            (carried to the next round)
        x'  = W φ̃

    The injected error is no longer persistent (it is re-sent next step), so
    the floor returns to the uncompressed level while DCI bytes halve.
    """
    dt = jnp.dtype(compress_dtype)

    def init(params: Params) -> State:
        return {"m": _zeros_like(params),
                "psi": jax.tree.map(jnp.asarray, params),
                "e": _zeros_like(params)}

    def step(params: Params, grads: Grads, state: State):
        m_new = _lincomb((beta, state["m"]), ((1.0 - beta), grads))
        psi_new = _lincomb((1.0, params), (-alpha, m_new))
        phi = _lincomb((1.0, psi_new), (1.0, params), (-1.0, state["psi"]))
        corr = _lincomb((1.0, phi), (1.0, state["e"]))
        payload = jax.tree.map(lambda c: c.astype(dt).astype(c.dtype), corr)
        e_new = _lincomb((1.0, corr), (-1.0, payload))
        new_params = mix(payload)
        return new_params, {"m": m_new, "psi": psi_new, "e": e_new}

    return DecOptimizer("edm_ef", init, step)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def make_dsgd(alpha: float, mix: Mixer, **_) -> DecOptimizer:
    """x ← W(x − α g)   (Lian et al. 2017; adapt-then-combine)."""

    def init(params):
        return {}

    def step(params, grads, state):
        return mix(_lincomb((1.0, params), (-alpha, grads))), state

    return DecOptimizer("dsgd", init, step)


def make_dmsgd(alpha: float, beta: float, mix: Mixer, **_) -> DecOptimizer:
    """DmSGD (Yu et al. 2019), eqs. (3.2)-(3.3) of the paper:
        m ← β m + (1-β) g ;  x ← W(x − α m).
    Suffers the O(α²ζ²/((1-β)²(1-λ)²)) inconsistency bias the paper removes.
    """

    def init(params):
        return {"m": _zeros_like(params)}

    def step(params, grads, state):
        m = _lincomb((beta, state["m"]), (1.0 - beta, grads))
        x = mix(_lincomb((1.0, params), (-alpha, m)))
        return x, {"m": m}

    return DecOptimizer("dmsgd", init, step)


def make_dsgt(alpha: float, mix: Mixer, **_) -> DecOptimizer:
    """DSGT (Zhang & You 2019; Pu & Nedić 2021), ATC form:

        y^t = W y^{t-1} + g^t − g^{t-1}        (gradient tracking)
        x^{t+1} = W (x^t − α y^t)

    State carries (y, g_prev, initialized-flag folded into g_prev=0, y=0:
    at t=0, y = g which matches the standard y⁰ = g⁰ initialization).
    """

    def init(params):
        return {"y": _zeros_like(params), "g_prev": _zeros_like(params)}

    def step(params, grads, state):
        y = _lincomb((1.0, mix(state["y"])), (1.0, grads), (-1.0, state["g_prev"]))
        x = mix(_lincomb((1.0, params), (-alpha, y)))
        return x, {"y": y, "g_prev": grads}

    return DecOptimizer("dsgt", init, step)


def make_dsgt_hb(alpha: float, beta: float, mix: Mixer, **_) -> DecOptimizer:
    """DSGT with heavy-ball momentum (Gao et al. 2023, DSGT-HB):

        y ← W y + g − g_prev
        m ← β m + (1-β) y
        x ← W (x − α m)
    """

    def init(params):
        return {"y": _zeros_like(params), "g_prev": _zeros_like(params),
                "m": _zeros_like(params)}

    def step(params, grads, state):
        y = _lincomb((1.0, mix(state["y"])), (1.0, grads), (-1.0, state["g_prev"]))
        m = _lincomb((beta, state["m"]), (1.0 - beta, y))
        x = mix(_lincomb((1.0, params), (-alpha, m)))
        return x, {"y": y, "g_prev": grads, "m": m}

    return DecOptimizer("dsgt_hb", init, step)


def make_decentlam(alpha: float, beta: float, mix: Mixer, **_) -> DecOptimizer:
    """DecentLaM (Yuan et al. 2021): momentum applied *outside* the gossip —

        m ← β m + (1-β) g ;  x ← W x − α m

    which removes the momentum-amplified bias of DmSGD but keeps the ζ² floor.
    """

    def init(params):
        return {"m": _zeros_like(params)}

    def step(params, grads, state):
        m = _lincomb((beta, state["m"]), (1.0 - beta, grads))
        x = _lincomb((1.0, mix(params)), (-alpha, m))
        return x, {"m": m}

    return DecOptimizer("decentlam", init, step)


def make_qg(alpha: float, beta: float, mix: Mixer, **_) -> DecOptimizer:
    """Quasi-Global momentum (Lin et al. 2021): the momentum buffer tracks the
    motion of the *locally observed global* iterate rather than raw gradients:

        x½ ← x − α (g + β m)
        x' ← W x½
        m  ← β m + (1-β) (x − x') / α
    """

    def init(params):
        return {"m": _zeros_like(params)}

    def step(params, grads, state):
        d = _lincomb((1.0, grads), (beta, state["m"]))
        x_new = mix(_lincomb((1.0, params), (-alpha, d)))
        m = _lincomb(
            (beta, state["m"]),
            ((1.0 - beta) / alpha, _lincomb((1.0, params), (-1.0, x_new))),
        )
        return x_new, {"m": m}

    return DecOptimizer("qg", init, step)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ALGORITHMS = {
    "edm": make_edm,
    "edm_ef": make_edm_ef,
    "ed": make_ed,
    "dsgd": make_dsgd,
    "dmsgd": make_dmsgd,
    "dsgt": make_dsgt,
    "dsgt_hb": make_dsgt_hb,
    "decentlam": make_decentlam,
    "qg": make_qg,
}


def make_optimizer(name: str, alpha: float, mix: Mixer, beta: float = 0.9,
                   **kwargs) -> DecOptimizer:
    if name not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {name!r}; have {sorted(ALGORITHMS)}")
    fn = ALGORITHMS[name]
    if name in ("dsgd", "dsgt", "ed"):
        return fn(alpha=alpha, mix=mix, **kwargs)
    return fn(alpha=alpha, beta=beta, mix=mix, **kwargs)
