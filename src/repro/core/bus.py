"""ParamBus: packed flat-buffer layout for the per-agent parameter set.

The EDM hot loop (DESIGN §5) is launch- and memory-bound when run *per
leaf*: a ~100-leaf transformer pays ~100 Pallas launches per fused update,
~100 `ppermute`s per gossip term, and per-leaf pad-to-tile waste.  The bus
packs the full per-agent pytree — params, grads, m, ψ — into ONE
``(A, rows, 128)`` superbuffer under a **static layout**, so the whole EDM
step runs bus-resident:

* one ``edm_update`` pallas_call over the entire bus (one grid);
* one ``ppermute`` per gossip term and one n-ary ``gossip_axpy`` combine
  per step (the mixing engines already operate leaf-wise over pytrees with
  a leading agent axis — a bus is simply a one-leaf tree);
* ``m``/``ψ`` stay in bus layout across steps (pack once at ``init_state``,
  unpack only for loss/grad and checkpointing).

Layout contract (DESIGN §5):

* lane width is fixed at 128 (:data:`~repro.kernels.edm_update.LANE`);
  every leaf's flattened elements start at an 8-row (8×128-element)
  boundary, so each leaf slot is independently VPU-tile-aligned;
* the buffer's total row count is rounded up to a multiple of
  ``block_rows · shards`` (default: the REPRO_BLOCK_ROWS-tunable kernel
  tile × the FSDP shard count, DESIGN §7) — the single tail pad region;
  all pad elements are zero and stay zero under the EDM update and any
  doubly-stochastic mix (both map 0 → 0), so the pad never contaminates
  logical values;
* shard-resident mode (``shards=S > 1``, DESIGN §7): the row axis is
  meant to be sharded S ways over the pod-internal mesh axis.  The
  rounding above guarantees ``rows % S == 0`` **and** that each shard's
  ``rows/S`` block is itself a whole number of kernel grid tiles, so
  every shard can run the fused kernels and the gossip permutes on its
  own row block without ever gathering;
* dtype policy: the bus carries one storage dtype (default f32); leaves
  are cast on pack and restored to their recorded dtype on unpack.  Any
  sub-f32 leaf (bf16/f16) round-trips losslessly through an f32 bus; a
  bf16 bus is the lossy wire-compression configuration and is only exact
  for bf16 leaves.

Policy groups (DESIGN §12): the bus is no longer one monolithic policy —
it is a small number of named **groups**, each owning a contiguous,
block-aligned row range plus its own gossip policy (schedule name,
``gossip_every`` cadence — 0 opts the group out of gossip entirely — and
wire format).  Leaves are assigned to groups by substring predicates over
their ``|``-joined pytree path (``blocks|0|moe|w_gate``); unmatched leaves
fall into a trailing ``"dense"`` group.  The default (no specs) is a
single ``"dense"`` group spanning the whole buffer whose layout is
bit-identical to the ungrouped layout — pinned by test.

Layouts are static Python objects (hashable, cached) — ``pack_tree`` /
``unpack_tree`` are pure jnp reshuffles, safe to trace under jit, and a
jitted step that closes over a layout never retraces on weight values.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = ["LANE", "BusLayout", "LeafSlot", "GroupSpec", "BusGroup",
           "make_layout", "layout_of", "group_specs_from_json", "leaf_paths",
           "pack_tree", "unpack_tree", "leaf_views", "padded_rows",
           "make_pipeline", "pipeline_payload", "pipeline_advance"]

LANE = 128  # must match repro.kernels.edm_update.LANE
_SUBLANE = 8  # 8×128 VPU tile: every leaf slot starts on an 8-row boundary


def padded_rows(n_elems: int, align: int = _SUBLANE) -> int:
    """Rows of 128 lanes holding ``n_elems``, rounded up to ``align`` rows."""
    rows = -(-n_elems // LANE)
    return -(-rows // align) * align


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Static placement of one pytree leaf inside the bus.

    ``shape``/``dtype`` are the *per-agent* logical leaf (agent axis
    stripped); the leaf occupies rows ``[row, row + rows)`` of the bus,
    elements ``[row·128, row·128 + size)`` of the flattened view.
    """

    row: int
    rows: int
    shape: Tuple[int, ...]
    dtype: Any
    size: int


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Declarative gossip policy for one set of leaves (DESIGN §12).

    ``match`` is a tuple of substring patterns tested against each leaf's
    ``|``-joined pytree path (e.g. ``("moe|w_gate",)`` matches every
    expert gate across all blocks); an empty tuple is a catch-all.  A
    callable ``path -> bool`` is also accepted (tests / exotic policies).

    ``gossip_every``: 1 = every gossip round, k > 1 = slow-cycle (the
    group mixes on steps where ``step % k == k-1``, with its own round
    clock ``step // k`` so no schedule round is gcd-aliased away),
    0 = full opt-out (local-only leaves — ships zero wire bytes, pinned
    in HLO).  ``wire``: per-group payload format ("f32"/"bf16"/"int8",
    stateless quantization — the error-feedback wire stays a run-level,
    single-group feature).  ``schedule``: gossip-schedule name override
    ("" inherits the run's schedule).
    """

    name: str
    match: Union[Tuple[str, ...], Callable[[str], bool]] = ()
    gossip_every: int = 1
    wire: str = "f32"
    schedule: str = ""

    def __post_init__(self):
        assert self.gossip_every >= 0, self.gossip_every
        assert self.wire in ("f32", "bf16", "int8"), self.wire
        if not callable(self.match):
            object.__setattr__(self, "match", tuple(self.match))

    def matches(self, path: str) -> bool:
        if callable(self.match):
            return bool(self.match(path))
        return any(p in path for p in self.match) if self.match else True


@dataclasses.dataclass(frozen=True)
class BusGroup:
    """Resolved policy group inside a layout: rows ``[row, row + rows)``
    of the bus, holding the slots indexed by ``slots`` (indices into
    ``layout.slots``), under one gossip policy.  ``rows`` is a whole
    multiple of ``block_rows · shards`` (or 0 if the group matched no
    leaves), so every group is independently griddable and shardable."""

    name: str
    row: int
    rows: int
    slots: Tuple[int, ...]
    gossip_every: int = 1
    wire: str = "f32"
    schedule: str = ""

    @property
    def elems(self) -> int:
        """Padded elements this group ships per agent per permute."""
        return self.rows * LANE


def group_specs_from_json(obj: Any) -> Tuple[GroupSpec, ...]:
    """Build group specs from a parsed ``--gossip-groups`` JSON list:
    ``[{"name": ..., "match": [...], "gossip_every": ..., "wire": ...,
    "schedule": ...}, ...]``.  ``match`` may be one pattern or a list."""
    assert isinstance(obj, (list, tuple)), obj
    specs = []
    for d in obj:
        assert isinstance(d, dict) and "name" in d, d
        match = d.get("match", ())
        if isinstance(match, str):
            match = (match,)
        specs.append(GroupSpec(
            name=str(d["name"]), match=tuple(match),
            gossip_every=int(d.get("gossip_every", 1)),
            wire=str(d.get("wire", "f32")),
            schedule=str(d.get("schedule", ""))))
    return tuple(specs)


@dataclasses.dataclass(frozen=True)
class BusLayout:
    """Static bus layout: where every leaf of the packed tree lives.

    Built from an example tree whose leaves carry a leading agent axis
    ``(A, *shape)``; the layout itself is agent-count-agnostic (``A`` is
    whatever ``pack_tree`` receives), which is why one cached layout backs
    init, the train step and checkpoint restore alike.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    rows: int                  # total rows incl. tail pad; % (block_rows·shards) == 0
    block_rows: int
    dtype: Any                 # bus storage dtype (f32 default)
    shards: int = 1            # FSDP row-shard count (DESIGN §7)
    groups: Tuple[BusGroup, ...] = ()  # policy groups, contiguous by row

    @property
    def is_grouped(self) -> bool:
        """True when the layout carries a non-trivial policy — more than
        one populated group, or a single group with a non-default policy.
        Ungrouped and trivially-grouped layouts take the legacy (single
        permute plan) mixing path and are bit-identical to it."""
        live = [g for g in self.groups if g.rows]
        if len(live) > 1:
            return True
        return any(g.gossip_every != 1 or g.wire != "f32" or g.schedule
                   for g in live)

    @property
    def shard_rows(self) -> int:
        """Rows each FSDP shard owns (``rows / shards``) — a whole number
        of ``block_rows`` grid tiles by layout construction."""
        assert self.rows % self.shards == 0, (self.rows, self.shards)
        return self.rows // self.shards

    @property
    def logical_elems(self) -> int:
        """Elements that carry data (excludes alignment + tail pad)."""
        return sum(s.size for s in self.slots)

    @property
    def padded_elems(self) -> int:
        """Total bus elements per agent (rows × 128) — what one permute of
        the bus actually ships, and what one kernel pass streams."""
        return self.rows * LANE

    @property
    def pad_waste(self) -> float:
        """Fraction of the bus that is alignment/tail padding."""
        return 1.0 - self.logical_elems / max(self.padded_elems, 1)


def _leaf_signature(tree: Any) -> tuple:
    # per-agent signature: the leading agent axis is stripped, so trees
    # differing only in A hit the same cached layout
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((tuple(l.shape[1:]), jnp.dtype(l.dtype).name)
                           for l in flat))


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return "|".join(out)


def leaf_paths(tree: Any) -> List[str]:
    """``|``-joined pytree path of every leaf, in flatten order — the
    strings :class:`GroupSpec` predicates match against (same separator as
    the checkpoint key flattening)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_path_str(p) for p, _ in flat]


_LAYOUT_CACHE: dict = {}


def make_layout(tree: Any, *, block_rows: int | None = None,
                dtype: Any = jnp.float32, shards: int = 1,
                groups: Optional[Tuple[GroupSpec, ...]] = None) -> BusLayout:
    """Build (or fetch from cache) the bus layout for ``tree``.

    ``tree`` leaves must be floating arrays (or ShapeDtypeStructs) of shape
    ``(A, *leaf_shape)`` — the leading agent axis is stripped; two trees
    differing only in ``A`` share one layout.  ``block_rows`` defaults to
    the kernel's :data:`~repro.kernels.edm_update.BLOCK_ROWS` so the packed
    buffer is directly griddable by ``edm_update_flat``.  ``shards`` rounds
    each group's rows up to ``block_rows · shards`` so the row axis splits
    evenly into per-shard blocks that are themselves griddable
    (shard-resident gossip, DESIGN §7).

    ``groups`` assigns leaves to policy groups (DESIGN §12): each leaf
    joins the first spec whose predicate matches its path; unmatched
    leaves fall into a trailing default ``"dense"`` group.  Groups occupy
    contiguous row ranges in spec order, each independently rounded to
    the ``block_rows · shards`` quantum.  ``groups=None`` (or a single
    catch-all spec) yields a layout bit-identical to the ungrouped bus.
    """
    from repro.kernels.edm_update import BLOCK_ROWS, LANE as _KERNEL_LANE
    assert _KERNEL_LANE == LANE, (
        "bus layout lane width drifted from the kernel grid", LANE,
        _KERNEL_LANE)
    if block_rows is None:
        block_rows = BLOCK_ROWS
    assert block_rows > 0 and block_rows % _SUBLANE == 0, block_rows
    assert shards >= 1, shards
    flat, treedef = jax.tree_util.tree_flatten(tree)
    assert flat, "cannot build a bus layout for an empty tree"
    specs = tuple(groups) if groups else (GroupSpec("dense"),)
    if not any((not callable(s.match)) and not s.match for s in specs):
        # no catch-all: unmatched leaves gossip normally in "dense"
        specs = specs + (GroupSpec("dense"),)
    names = [s.name for s in specs]
    assert len(set(names)) == len(names), f"duplicate group names: {names}"
    key = (_leaf_signature(tree), block_rows, jnp.dtype(dtype).name, shards,
           specs)
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None:
        return hit
    paths = leaf_paths(tree)
    members: List[List[int]] = [[] for _ in specs]
    for i, path in enumerate(paths):
        for gi, spec in enumerate(specs):
            if spec.matches(path):
                members[gi].append(i)
                break
    quantum = block_rows * shards
    slot_at: List[Optional[LeafSlot]] = [None] * len(flat)
    resolved: List[BusGroup] = []
    base = 0
    for spec, idxs in zip(specs, members):
        row = base
        for i in idxs:
            leaf = flat[i]
            assert leaf.ndim >= 1, "bus leaves need a leading agent axis"
            assert jnp.issubdtype(leaf.dtype, jnp.floating), \
                f"bus packs floating leaves only, got {leaf.dtype}"
            shape = tuple(leaf.shape[1:])
            size = 1
            for s in shape:
                size *= s
            rows = padded_rows(size)
            slot_at[i] = LeafSlot(row, rows, shape, jnp.dtype(leaf.dtype),
                                  size)
            row += rows
        used = row - base
        grows = -(-used // quantum) * quantum if used else 0
        resolved.append(BusGroup(spec.name, base, grows, tuple(idxs),
                                 spec.gossip_every, spec.wire, spec.schedule))
        base += grows
    total = base if base else quantum
    assert all(s is not None for s in slot_at)
    layout = BusLayout(treedef, tuple(slot_at), total, block_rows,
                       jnp.dtype(dtype), shards, tuple(resolved))
    _LAYOUT_CACHE[key] = layout
    return layout


def layout_of(model, n_agents: int, *, block_rows: int | None = None,
              dtype: Any = jnp.float32, shards: int = 1,
              groups: Optional[Tuple[GroupSpec, ...]] = None) -> BusLayout:
    """Layout for a :class:`~repro.models.api.Model`'s parameter tree with
    a leading agent axis — shape-only (``jax.eval_shape``), no allocation."""
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    lifted = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_agents,) + tuple(s.shape), s.dtype),
        shapes)
    return make_layout(lifted, block_rows=block_rows, dtype=dtype,
                       shards=shards, groups=groups)


def pack_tree(layout: BusLayout, tree: Any) -> jax.Array:
    """Pack ``tree`` (leaves ``(A, *shape)``) into one ``(A, rows, 128)``
    buffer in bus dtype.  Pure jnp; pad elements are zero.  Segments are
    emitted in physical row order (slot rows are not monotone in flatten
    order once the layout is grouped), with zero-fill for every group's
    tail pad."""
    flat = layout.treedef.flatten_up_to(tree)
    assert len(flat) == len(layout.slots)
    A = flat[0].shape[0]
    parts = []
    cursor = 0  # in elements of the (A, rows·128) flat view
    order = sorted(range(len(flat)), key=lambda i: layout.slots[i].row)
    for i in order:
        leaf, slot = flat[i], layout.slots[i]
        assert leaf.shape == (A,) + slot.shape, (leaf.shape, A, slot.shape)
        gap = slot.row * LANE - cursor
        assert gap >= 0, (slot.row, cursor)
        if gap:
            parts.append(jnp.zeros((A, gap), layout.dtype))
        seg = leaf.reshape(A, slot.size).astype(layout.dtype)
        pad = slot.rows * LANE - slot.size
        if pad:
            seg = jnp.pad(seg, ((0, 0), (0, pad)))
        parts.append(seg)
        cursor = (slot.row + slot.rows) * LANE
    tail = layout.rows * LANE - cursor
    if tail:
        parts.append(jnp.zeros((A, tail), layout.dtype))
    return jnp.concatenate(parts, axis=1).reshape(A, layout.rows, LANE)


def _slot_views(layout: BusLayout, bus: jax.Array):
    """Flat per-slot ``(A, *leaf_shape)`` views of the bus (bus dtype) —
    the single slicing loop behind :func:`unpack_tree` and
    :func:`leaf_views`."""
    A, rows, lane = bus.shape
    assert rows == layout.rows and lane == LANE, (bus.shape, layout.rows)
    flat_view = bus.reshape(A, rows * LANE)
    out = []
    for slot in layout.slots:
        start = slot.row * LANE
        seg = jax.lax.slice_in_dim(flat_view, start, start + slot.size,
                                   axis=1)
        out.append(seg.reshape((A,) + slot.shape))
    return out


def unpack_tree(layout: BusLayout, bus: jax.Array) -> Any:
    """Inverse of :func:`pack_tree`: restore the logical pytree (per-leaf
    shapes and dtypes) from an ``(A, rows, 128)`` bus buffer."""
    leaves = [v.astype(slot.dtype)
              for v, slot in zip(_slot_views(layout, bus), layout.slots)]
    return jax.tree_util.tree_unflatten(layout.treedef, leaves)


# ---------------------------------------------------------------------------
# double-buffered pipeline slots (DESIGN §6)
# ---------------------------------------------------------------------------
#
# The overlapped gossip pipeline carries its in-flight payload in the train
# state: ``slot`` is a (2, A, rows, 128) stack of two bus buffers and
# ``parity`` a replicated int32 bit selecting the LIVE one.  Step t reads
# slot[parity] (its permutes are issued before the backward pass), writes the
# freshly produced payload φ' into slot[1−parity], and flips the bit — so the
# buffer a collective is still reading is never the one the EDM update
# writes, and a donated step aliases both slots in place with no
# write-after-read hazard between the wire and the update.

def make_pipeline(bus: jax.Array) -> dict:
    """Initial pipeline state: ``bus`` (= φ(0) = x(0)) in the live slot,
    zeros in the spare, parity 0."""
    assert bus.ndim == 3 and bus.shape[-1] == LANE, bus.shape
    return {"slot": jnp.stack([bus, jnp.zeros_like(bus)]),
            "parity": jnp.zeros((), jnp.int32)}


def pipeline_payload(pipe: dict) -> jax.Array:
    """The live in-flight payload ``slot[parity]`` — what this step's gossip
    permutes ship (parity is replicated, so the dynamic index is
    SPMD-consistent)."""
    return jax.lax.dynamic_index_in_dim(pipe["slot"], pipe["parity"], axis=0,
                                        keepdims=False)


def pipeline_advance(pipe: dict, phi_new: jax.Array) -> dict:
    """Write the next payload into the spare slot and flip the parity bit.
    The old live slot's contents become dead but stay allocated — that's the
    double buffer."""
    slot = jax.lax.dynamic_update_index_in_dim(pipe["slot"], phi_new,
                                               1 - pipe["parity"], axis=0)
    return {"slot": slot, "parity": 1 - pipe["parity"]}


def leaf_views(layout: BusLayout, bus: jax.Array) -> Any:
    """Per-leaf *bus-dtype* views of the packed buffer, as a pytree matching
    the layout's structure: each view is ``(A, *leaf_shape)`` in the bus
    storage dtype (no cast back — useful for in-layout diagnostics like
    per-leaf norms without a full unpack)."""
    return jax.tree_util.tree_unflatten(layout.treedef,
                                        _slot_views(layout, bus))
