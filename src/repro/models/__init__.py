"""repro.models — composable model definitions for the assigned architectures."""
from .api import Model, build_model, input_specs, batch_specs  # noqa: F401
