"""GQA attention: full-causal, sliding-window, cross, and cached decode.

The jnp path here is the reference used for training/dry-run lowering; the
Pallas flash kernel (repro.kernels.flash_attention) is the TPU hot path and is
validated against :func:`sdpa_ref` in tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm, rope

__all__ = ["init_attn", "apply_attn", "apply_attn_paged", "init_kv_cache",
           "sdpa_ref"]

NEG_INF = -1e30

# §Perf lever: keep the attention *data path* (logits → probs → out) in bf16
# — mirrors the Pallas flash kernel, whose f32 accumulators live in VMEM while
# HBM-crossing tensors stay bf16.  Halves the activation-cotangent collective
# payloads that otherwise ride the f32 jnp reference path.
_BF16_PATH = {"on": False}


def set_bf16_path(flag: bool) -> None:
    _BF16_PATH["on"] = bool(flag)


def init_attn(key, cfg, cross: bool = False) -> Dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, H * hd), 0, dt),
        "wk": dense_init(ks[1], (d, K * hd), 0, dt),
        "wv": dense_init(ks[2], (d, K * hd), 0, dt),
        "wo": dense_init(ks[3], (H * hd, d), 0, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def sdpa_ref(q, k, v, *, causal: bool, window: int = 0,
             q_offset: int = 0, kv_len: Optional[jax.Array] = None):
    """Scaled dot-product attention with GQA head sharing.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd).  H % K == 0.
    ``q_offset``: absolute position of q[0] (for cached decode).
    ``kv_len``:   optional dynamic number of valid kv entries (decode);
                  a scalar, or a ``(B,)`` array for ragged slot batches
                  (the continuous-batching engine, DESIGN §10).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    bf16_path = _BF16_PATH["on"] and q.dtype == jnp.bfloat16
    acc_dt = q.dtype if bf16_path else jnp.float32
    qf = q.astype(acc_dt).reshape(B, Sq, K, G, hd)
    kf = k.astype(acc_dt)
    vf = v.astype(acc_dt)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qf * scale, kf,
                        preferred_element_type=jnp.float32)  # (B,K,G,Sq,Sk)
    Sk = k.shape[1]
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    lens = None if kv_len is None else jnp.asarray(kv_len)
    if lens is not None and lens.ndim == 1:
        # ragged slot batch: per-slot valid-kv mask (decode-only shapes,
        # Sq = 1 — the (B, Sq, Sk) mask never rides the training path)
        bmask = mask[None] & (k_pos[None, None, :] < lens[:, None, None])
        logits = jnp.where(bmask[:, None, None], logits, NEG_INF)
    else:
        if lens is not None:
            mask &= k_pos[None, :] < lens
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(acc_dt)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, vf)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _qkv(p, cfg, x, positions):
    use_rope = getattr(cfg, "pos_emb", "rope") == "rope"
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def init_kv_cache(cfg, batch: int, length: int, dtype=None):
    K, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, length, K, hd), dt),
        "v": jnp.zeros((batch, length, K, hd), dt),
    }


def apply_attn(p, cfg, x, positions, *, mode: str = "train",
               cache: Optional[Dict] = None, window: int = 0,
               cur_len: Optional[jax.Array] = None,
               xattn_kv: Optional[Tuple] = None) -> Tuple:
    """Attention sub-block with pre-norm + residual.

    mode:
      "train"   — full (or sliding-window) causal self-attention.
      "prefill" — as train, but also fills and returns the cache.
      "decode"  — single-step (Sq=1) with ring-buffer/linear cache update.
      "cross"   — encoder-decoder cross attention (xattn_kv = (k, v)).
    Returns (y, new_cache).
    """
    resid = x
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    win = window or cfg.sliding_window

    if mode == "cross":
        B, S, d = h.shape
        H, hd = cfg.n_heads, cfg.hd
        q = (h @ p["wq"]).reshape(B, S, H, hd)
        k, v = xattn_kv
        out = sdpa_ref(q, k, v, causal=False)
        y = out.reshape(B, S, H * hd) @ p["wo"]
        return resid + y, cache

    if mode in ("train", "prefill"):
        q, k, v = _qkv(p, cfg, h, positions)
        out = sdpa_ref(q, k, v, causal=True, window=win)
        new_cache = None
        if mode == "prefill":
            if win and k.shape[1] > win:
                # keep the last `win` entries, rolled so that ring-buffer slot
                # of position p is p % win (decode-compatible layout).
                S = k.shape[1]
                k_w, v_w = k[:, -win:], v[:, -win:]
                shift = (S - win) % win
                new_cache = {"k": jnp.roll(k_w, shift, axis=1),
                             "v": jnp.roll(v_w, shift, axis=1)}
            else:
                new_cache = {"k": k, "v": v}
        B, S = h.shape[:2]
        y = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
        return resid + y, new_cache

    assert mode == "decode" and cache is not None
    # one new token; positions: (B, 1) absolute position of the new token
    q, k_new, v_new = _qkv(p, cfg, h, positions)
    L = cache["k"].shape[1]
    if win and L == win:
        # ring buffer: slot = pos mod window
        slot = positions[0, 0] % win
    else:
        slot = cur_len if cur_len is not None else positions[0, 0]
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    if win and L == win:
        # every occupied slot is within the window → plain full attention over
        # the ring buffer (positions beyond cur fill are zero-keyed but masked
        # by kv_len when the buffer is not yet full).
        n_valid = jnp.minimum(positions[0, 0] + 1, win)
        out = sdpa_ref(q, k, v, causal=False, kv_len=n_valid)
    else:
        out = sdpa_ref(q, k, v, causal=False, kv_len=positions[0, 0] + 1)
    B = h.shape[0]
    y = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return resid + y, {"k": k, "v": v}


def _gather_pages(pool, page_table):
    """(num_pages, page_size, K, hd) × (B, n_pages) → dense
    (B, n_pages·page_size, K, hd) view — the same op sequence as
    :func:`repro.kernels.ref.gather_pages` (kept local: kernels imports
    this module for ``sdpa_ref``)."""
    B, n_pages = page_table.shape
    _, page_size, K, hd = pool.shape
    dense = jnp.take(pool, page_table.reshape(-1), axis=0)
    return dense.reshape(B, n_pages * page_size, K, hd)


def apply_attn_paged(p, cfg, x, positions, *, pools, page_table, kv_len,
                     window: int = 0, attn_fn=None) -> Tuple:
    """Paged decode attention sub-block (DESIGN §10): one token per slot,
    KV read/written through a page table instead of a contiguous cache.

    x: (B, 1, d) slot-batched new-token activations; positions: (B, 1)
    per-slot absolute position of the new token (ragged — unlike
    :func:`apply_attn`'s uniform decode ``pos``); pools: {"k","v"} page
    pools ``(num_pages, page_size, K, hd)``; page_table: (B, n_pages)
    physical-page ids; kv_len: (B,) valid KV rows to attend over
    *including* the row written here — the scheduler passes 0 for idle
    slots, whose writes sink to the null page and whose output is junk
    that the active mask discards.

    ``attn_fn(q, k_pool, v_pool, page_table, kv_len) -> (B, K, G, hd)``
    selects the attention backend (the Pallas paged kernel); ``None``
    runs the pure-jnp gather + ``sdpa_ref`` reference — the exact op
    sequence of :func:`repro.kernels.ref.paged_attention_ref`, which the
    bit-exact engine-vs-dense gate relies on.

    Returns (y, new_pools).
    """
    resid = x
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k_new, v_new = _qkv(p, cfg, h, positions)

    B = x.shape[0]
    page_size = pools["k"].shape[1]
    # logical write row: absolute position, folded onto the ring in
    # window mode (same layout as the dense ring cache: row = pos % win)
    row = positions[:, 0] % window if window else positions[:, 0]
    phys = page_table[jnp.arange(B), row // page_size]        # (B,)
    rin = row % page_size
    # idle slots (page-table row all NULL) scatter into the null page —
    # duplicate (0, 0) targets collide only with each other, never with a
    # live slot's pages (allocator invariant).
    k_pool = pools["k"].at[phys, rin].set(k_new[:, 0])
    v_pool = pools["v"].at[phys, rin].set(v_new[:, 0])

    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qg = q.reshape(B, K, H // K, hd)
    if attn_fn is None:
        k = _gather_pages(k_pool, page_table)
        v = _gather_pages(v_pool, page_table)
        out = sdpa_ref(q, k, v, causal=False, kv_len=kv_len)
    else:
        out = attn_fn(qg, k_pool, v_pool, page_table, kv_len)
        out = out.reshape(B, 1, H, hd)
    y = out.reshape(B, 1, H * hd) @ p["wo"]
    return resid + y, {"k": k_pool, "v": v_pool}
