"""GQA attention: full-causal, sliding-window, cross, and cached decode.

The jnp path here is the reference used for training/dry-run lowering; the
Pallas flash kernel (repro.kernels.flash_attention) is the TPU hot path and is
validated against :func:`sdpa_ref` in tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm, rope

__all__ = ["init_attn", "apply_attn", "apply_attn_paged",
           "apply_attn_paged_prefill", "init_kv_cache", "sdpa_ref",
           "sdpa_pos_ref", "prev_page_positions", "paged_prefill_sdpa"]

NEG_INF = -1e30

# §Perf lever: keep the attention *data path* (logits → probs → out) in bf16
# — mirrors the Pallas flash kernel, whose f32 accumulators live in VMEM while
# HBM-crossing tensors stay bf16.  Halves the activation-cotangent collective
# payloads that otherwise ride the f32 jnp reference path.
_BF16_PATH = {"on": False}


def set_bf16_path(flag: bool) -> None:
    _BF16_PATH["on"] = bool(flag)


def init_attn(key, cfg, cross: bool = False) -> Dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln": jnp.zeros((d,), dt),
        "wq": dense_init(ks[0], (d, H * hd), 0, dt),
        "wk": dense_init(ks[1], (d, K * hd), 0, dt),
        "wv": dense_init(ks[2], (d, K * hd), 0, dt),
        "wo": dense_init(ks[3], (H * hd, d), 0, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def sdpa_ref(q, k, v, *, causal: bool, window: int = 0,
             q_offset: int = 0, kv_len: Optional[jax.Array] = None):
    """Scaled dot-product attention with GQA head sharing.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd).  H % K == 0.
    ``q_offset``: absolute position of q[0] (for cached decode).
    ``kv_len``:   optional dynamic number of valid kv entries (decode);
                  a scalar, or a ``(B,)`` array for ragged slot batches
                  (the continuous-batching engine, DESIGN §10).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    bf16_path = _BF16_PATH["on"] and q.dtype == jnp.bfloat16
    acc_dt = q.dtype if bf16_path else jnp.float32
    qf = q.astype(acc_dt).reshape(B, Sq, K, G, hd)
    kf = k.astype(acc_dt)
    vf = v.astype(acc_dt)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qf * scale, kf,
                        preferred_element_type=jnp.float32)  # (B,K,G,Sq,Sk)
    Sk = k.shape[1]
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    lens = None if kv_len is None else jnp.asarray(kv_len)
    if lens is not None and lens.ndim == 1:
        # ragged slot batch: per-slot valid-kv mask (decode-only shapes,
        # Sq = 1 — the (B, Sq, Sk) mask never rides the training path)
        bmask = mask[None] & (k_pos[None, None, :] < lens[:, None, None])
        logits = jnp.where(bmask[:, None, None], logits, NEG_INF)
    else:
        if lens is not None:
            mask &= k_pos[None, :] < lens
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(acc_dt)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, vf)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def sdpa_pos_ref(q, k, v, *, q_pos, k_pos, k_valid, window: int = 0):
    """GQA SDPA with EXPLICIT per-row key positions and validity — the
    chunked-prefill reference (DESIGN §11), where the key rows are a mix
    of ring/linear page rows and the in-flight chunk so neither positions
    nor validity are derivable from row indices.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd); q_pos: (Sq,) absolute query
    positions; k_pos: (Sk,) absolute key positions; k_valid: (Sk,) bool.
    Masking: valid ∧ causal (k_pos ≤ q_pos) ∧ window (k_pos > q_pos − w).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, K, G, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qf * scale,
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    mask = k_valid[None, :] & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def prev_page_positions(n_rows, chunk_start, window: int = 0):
    """(positions, valid) of the previously-filled page rows a prefill
    chunk starting at absolute position ``chunk_start`` attends to.

    Linear (``window == 0``): row r holds position r, valid iff
    r < chunk_start.  Ring: row r holds the LATEST position p < chunk_start
    with p ≡ r (mod window) — ``(chunk_start−1) − ((chunk_start−1−r) mod
    window)`` — valid iff that position exists (p ≥ 0); the occupied rows
    are exactly the prefix [0, min(chunk_start, window))."""
    r = jnp.arange(n_rows, dtype=jnp.int32)
    start = jnp.asarray(chunk_start, jnp.int32)
    if window:
        pos = (start - 1) - jnp.mod(start - 1 - r, window)
        # rows past the ring (NULL page-table entries) alias in-window
        # positions through the mod — only the ring's own rows are real
        valid = (pos >= 0) & (pos < start) & (r < window)
    else:
        pos = r
        valid = (pos >= 0) & (pos < start)
    return pos, valid


def paged_prefill_sdpa(q, k_chunk, v_chunk, k_pool, v_pool, pt_row,
                       chunk_start, chunk_len, *, window: int = 0):
    """Pure-jnp chunked-prefill attention (DESIGN §11): chunk queries
    attend causally to every previously-filled page row of ONE slot
    (gathered through its page-table row) plus the in-flight chunk's own
    keys — the chunk K/V ride alongside rather than through the pool, so
    ring rows the chunk is about to overwrite are still read at their
    pre-chunk values.

    q: (1, C, H, hd); k_chunk, v_chunk: (1, C, K, hd); k_pool, v_pool:
    (num_pages, page_size, K, hd); pt_row: (n_pages,) physical page ids;
    chunk_start: absolute position of q[0]; chunk_len: valid chunk rows
    (the last chunk is padded — rows ≥ chunk_len are masked everywhere).

    This is both the ``attn_impl="ref"`` op sequence and (via
    :func:`repro.kernels.ref.paged_prefill_attention_ref`) the oracle the
    Pallas paged-prefill kernel is tested against."""
    C = q.shape[1]
    k_prev = _gather_pages(k_pool, pt_row[None])      # (1, R, K, hd)
    v_prev = _gather_pages(v_pool, pt_row[None])
    kpos_prev, valid_prev = prev_page_positions(k_prev.shape[1],
                                                chunk_start, window)
    # sanitize never-written rows: masked logits already exclude them, but
    # 0·NaN = NaN in the value matmul would leak pool poison (DESIGN §10)
    dead = ~valid_prev[None, :, None, None]
    k_prev = jnp.where(dead, 0.0, k_prev).astype(k_prev.dtype)
    v_prev = jnp.where(dead, 0.0, v_prev).astype(v_prev.dtype)
    qpos = (jnp.asarray(chunk_start, jnp.int32)
            + jnp.arange(C, dtype=jnp.int32))
    k_all = jnp.concatenate([k_prev, k_chunk], axis=1)
    v_all = jnp.concatenate([v_prev, v_chunk], axis=1)
    k_pos = jnp.concatenate([kpos_prev, qpos])
    k_valid = jnp.concatenate(
        [valid_prev, jnp.arange(C) < jnp.asarray(chunk_len, jnp.int32)])
    return sdpa_pos_ref(q, k_all, v_all, q_pos=qpos, k_pos=k_pos,
                        k_valid=k_valid, window=window)


def _qkv(p, cfg, x, positions):
    use_rope = getattr(cfg, "pos_emb", "rope") == "rope"
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def init_kv_cache(cfg, batch: int, length: int, dtype=None):
    K, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, length, K, hd), dt),
        "v": jnp.zeros((batch, length, K, hd), dt),
    }


def apply_attn(p, cfg, x, positions, *, mode: str = "train",
               cache: Optional[Dict] = None, window: int = 0,
               cur_len: Optional[jax.Array] = None,
               xattn_kv: Optional[Tuple] = None) -> Tuple:
    """Attention sub-block with pre-norm + residual.

    mode:
      "train"   — full (or sliding-window) causal self-attention.
      "prefill" — as train, but also fills and returns the cache.
      "decode"  — single-step (Sq=1) with ring-buffer/linear cache update.
      "cross"   — encoder-decoder cross attention (xattn_kv = (k, v)).
    Returns (y, new_cache).
    """
    resid = x
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    win = window or cfg.sliding_window

    if mode == "cross":
        B, S, d = h.shape
        H, hd = cfg.n_heads, cfg.hd
        q = (h @ p["wq"]).reshape(B, S, H, hd)
        k, v = xattn_kv
        out = sdpa_ref(q, k, v, causal=False)
        y = out.reshape(B, S, H * hd) @ p["wo"]
        return resid + y, cache

    if mode in ("train", "prefill"):
        q, k, v = _qkv(p, cfg, h, positions)
        out = sdpa_ref(q, k, v, causal=True, window=win)
        new_cache = None
        if mode == "prefill":
            if win and k.shape[1] > win:
                # keep the last `win` entries, rolled so that ring-buffer slot
                # of position p is p % win (decode-compatible layout).
                S = k.shape[1]
                k_w, v_w = k[:, -win:], v[:, -win:]
                shift = (S - win) % win
                new_cache = {"k": jnp.roll(k_w, shift, axis=1),
                             "v": jnp.roll(v_w, shift, axis=1)}
            else:
                new_cache = {"k": k, "v": v}
        B, S = h.shape[:2]
        y = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
        return resid + y, new_cache

    assert mode == "decode" and cache is not None
    # one new token; positions: (B, 1) absolute position of the new token
    q, k_new, v_new = _qkv(p, cfg, h, positions)
    L = cache["k"].shape[1]
    if win and L == win:
        # ring buffer: slot = pos mod window
        slot = positions[0, 0] % win
    else:
        slot = cur_len if cur_len is not None else positions[0, 0]
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    if win and L == win:
        # every occupied slot is within the window → plain full attention over
        # the ring buffer (positions beyond cur fill are zero-keyed but masked
        # by kv_len when the buffer is not yet full).
        n_valid = jnp.minimum(positions[0, 0] + 1, win)
        out = sdpa_ref(q, k, v, causal=False, kv_len=n_valid)
    else:
        out = sdpa_ref(q, k, v, causal=False, kv_len=positions[0, 0] + 1)
    B = h.shape[0]
    y = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return resid + y, {"k": k, "v": v}


def _gather_pages(pool, page_table):
    """(num_pages, page_size, K, hd) × (B, n_pages) → dense
    (B, n_pages·page_size, K, hd) view — the same op sequence as
    :func:`repro.kernels.ref.gather_pages` (kept local: kernels imports
    this module for ``sdpa_ref``)."""
    B, n_pages = page_table.shape
    _, page_size, K, hd = pool.shape
    dense = jnp.take(pool, page_table.reshape(-1), axis=0)
    return dense.reshape(B, n_pages * page_size, K, hd)


def apply_attn_paged(p, cfg, x, positions, *, pools, page_table, kv_len,
                     window: int = 0, attn_fn=None) -> Tuple:
    """Paged decode attention sub-block (DESIGN §10): one token per slot,
    KV read/written through a page table instead of a contiguous cache.

    x: (B, 1, d) slot-batched new-token activations; positions: (B, 1)
    per-slot absolute position of the new token (ragged — unlike
    :func:`apply_attn`'s uniform decode ``pos``); pools: {"k","v"} page
    pools ``(num_pages, page_size, K, hd)``; page_table: (B, n_pages)
    physical-page ids; kv_len: (B,) valid KV rows to attend over
    *including* the row written here — the scheduler passes 0 for idle
    slots, whose writes sink to the null page and whose output is junk
    that the active mask discards.

    ``attn_fn(q, k_pool, v_pool, page_table, kv_len) -> (B, K, G, hd)``
    selects the attention backend (the Pallas paged kernel); ``None``
    runs the pure-jnp gather + ``sdpa_ref`` reference — the exact op
    sequence of :func:`repro.kernels.ref.paged_attention_ref`, which the
    bit-exact engine-vs-dense gate relies on.

    Returns (y, new_pools).
    """
    resid = x
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k_new, v_new = _qkv(p, cfg, h, positions)

    B = x.shape[0]
    page_size = pools["k"].shape[1]
    # logical write row: absolute position, folded onto the ring in
    # window mode (same layout as the dense ring cache: row = pos % win)
    row = positions[:, 0] % window if window else positions[:, 0]
    phys = page_table[jnp.arange(B), row // page_size]        # (B,)
    rin = row % page_size
    # idle slots (page-table row all NULL) scatter into the null page —
    # duplicate (0, 0) targets collide only with each other, never with a
    # live slot's pages (allocator invariant).
    k_pool = pools["k"].at[phys, rin].set(k_new[:, 0])
    v_pool = pools["v"].at[phys, rin].set(v_new[:, 0])

    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    qg = q.reshape(B, K, H // K, hd)
    if attn_fn is None:
        k = _gather_pages(k_pool, page_table)
        v = _gather_pages(v_pool, page_table)
        out = sdpa_ref(q, k, v, causal=False, kv_len=kv_len)
    else:
        out = attn_fn(qg, k_pool, v_pool, page_table, kv_len)
        out = out.reshape(B, 1, H, hd)
    y = out.reshape(B, 1, H * hd) @ p["wo"]
    return resid + y, {"k": k_pool, "v": v_pool}


def apply_attn_paged_prefill(p, cfg, x, *, pools, pt_row, chunk_start,
                             chunk_len, window: int = 0,
                             attn_fn=None) -> Tuple:
    """Chunked-prefill attention sub-block (DESIGN §11): one fixed-size
    chunk of ONE slot's prompt, attending over that slot's
    previously-filled pages plus itself, then scattered into the pages.

    x: (1, C, d) chunk activations (C is the STATIC chunk width — the
    whole serving trace compiles this shape once); pt_row: (n_pages,)
    the slot's page-table row; chunk_start: absolute position of x[:, 0]
    (the slot's prefill cursor); chunk_len: traced valid-token count —
    the last chunk of a prompt is padded, and padded rows are masked out
    of the attention AND their page writes sink to the null page.

    Attention runs BEFORE the write: in ring mode a chunk's rows alias
    ring rows that still hold live pre-chunk keys (position p − window is
    in-window for early chunk queries), so write-then-attend would read
    the overwritten values.  ``attn_fn(q, k_chunk, v_chunk, k_pool,
    v_pool, pt_row, chunk_start, chunk_len) -> (1, C, H, hd)`` selects
    the Pallas paged-prefill kernel; ``None`` runs
    :func:`paged_prefill_sdpa` — the oracle's exact op sequence.

    Returns (y (1, C, d), new_pools).
    """
    resid = x
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    C = x.shape[1]
    qpos = (jnp.asarray(chunk_start, jnp.int32)
            + jnp.arange(C, dtype=jnp.int32))
    q, k_new, v_new = _qkv(p, cfg, h, qpos[None])
    if attn_fn is None:
        out = paged_prefill_sdpa(q, k_new, v_new, pools["k"], pools["v"],
                                 pt_row, chunk_start, chunk_len,
                                 window=window)
    else:
        out = attn_fn(q, k_new, v_new, pools["k"], pools["v"], pt_row,
                      chunk_start, chunk_len)
    # scatter the chunk's VALID rows into the slot's pages; padded rows
    # redirect to physical page 0 (the null write sink — same idiom as
    # idle decode slots, see apply_attn_paged).  Ring rows are distinct
    # within one chunk because the engine enforces C <= window.
    page_size = pools["k"].shape[1]
    row = jnp.mod(qpos, window) if window else qpos
    live = jnp.arange(C) < jnp.asarray(chunk_len, jnp.int32)
    phys = jnp.where(live, pt_row[row // page_size], 0)
    rin = row % page_size
    k_pool = pools["k"].at[phys, rin].set(k_new[0])
    v_pool = pools["v"].at[phys, rin].set(v_new[0])
    y = out.reshape(1, C, cfg.n_heads * cfg.hd) @ p["wo"]
    return resid + y, {"k": k_pool, "v": v_pool}
