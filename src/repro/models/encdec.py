"""Whisper-style encoder-decoder backbone (audio family).

Per the brief, the mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, T_enc, d_model).
The encoder is bidirectional self-attention; the decoder is causal
self-attention + cross-attention.  Positional information is sinusoidal
(computed on the fly — adaptation from Whisper's learned 448-entry table so
the assigned 32k/500k decode shapes lower mechanically; recorded in DESIGN).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from .attention import apply_attn, init_attn, init_kv_cache, sdpa_ref
from .layers import apply_dense_ffn, dense_init, init_dense_ffn, rms_norm
from .transformer import _attn_specs, _ffn_specs, _prepend

__all__ = [
    "init_encdec", "encdec_loss", "encdec_prefill", "encdec_decode_step",
    "init_encdec_cache", "encdec_param_specs", "encdec_cache_specs",
    "N_AUDIO_FRAMES",
]

N_AUDIO_FRAMES = 1500  # whisper 30 s @ 50 Hz after conv frontend


def _sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"attn": init_attn(k1, cfg),
            "ffn": init_dense_ffn(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated,
                                  jnp.dtype(cfg.dtype))}


def _init_dec_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": init_attn(k1, cfg),
            "xattn": init_attn(k2, cfg),
            "ffn": init_dense_ffn(k3, cfg.d_model, cfg.d_ff, cfg.mlp_gated,
                                  jnp.dtype(cfg.dtype))}


def init_encdec(cfg: ModelConfig, key) -> Dict:
    ks = jax.random.split(key, 4)
    ekeys = jax.random.split(ks[0], cfg.n_enc_layers)
    dkeys = jax.random.split(ks[1], cfg.n_layers)
    dt = jnp.dtype(cfg.dtype)
    return {
        "enc_blocks": jax.vmap(functools.partial(_init_enc_layer, cfg))(ekeys),
        "enc_ln": jnp.zeros((cfg.d_model,), dt),
        "dec_embed": dense_init(ks[2], (cfg.vocab_size, cfg.d_model), 1, dt),
        "dec_blocks": jax.vmap(functools.partial(_init_dec_layer, cfg))(dkeys),
        "dec_ln": jnp.zeros((cfg.d_model,), dt),
        "lm_head": dense_init(ks[3], (cfg.d_model, cfg.vocab_size), 0, dt),
    }


def encdec_param_specs(cfg: ModelConfig) -> Dict:
    enc = {"attn": _attn_specs(cfg), "ffn": _ffn_specs(cfg, cfg.mlp_gated)}
    dec = {"attn": _attn_specs(cfg), "xattn": _attn_specs(cfg),
           "ffn": _ffn_specs(cfg, cfg.mlp_gated)}
    lift = lambda t: jax.tree.map(lambda s: _prepend(s, None), t,
                                  is_leaf=lambda s: isinstance(s, P))
    return {
        "enc_blocks": lift(enc), "enc_ln": P(None),
        "dec_embed": P("model", None),
        "dec_blocks": lift(dec), "dec_ln": P(None),
        "lm_head": P(None, "model"),
    }


def _encode(cfg, params, frames, unroll=False):
    """frames: (B, T_enc, d) stub embeddings → encoder output."""
    B, T, d = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x = frames + _sinusoid(pos, d).astype(frames.dtype)

    # encoder needs non-causal attention; specialised body:
    def enc_body(x, p):
        resid = x
        h = rms_norm(x, p["attn"]["ln"], cfg.norm_eps)
        from .attention import _qkv
        q, k, v = _qkv(p["attn"], cfg, h, pos)
        out = sdpa_ref(q, k, v, causal=False)
        y = out.reshape(B, T, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
        x = resid + y
        x = apply_dense_ffn(p["ffn"], x, cfg.norm_eps)
        return x, None

    x, _ = jax.lax.scan(enc_body, x, params["enc_blocks"], unroll=unroll)
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def _cross_kv(cfg, p_x, enc_out):
    B, T, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p_x["wk"]).reshape(B, T, K, hd)
    v = (enc_out @ p_x["wv"]).reshape(B, T, K, hd)
    return k, v


def _decode_stack(cfg, params, x, positions, enc_out, *, mode, caches=None,
                  window=0, unroll=False):
    B = x.shape[0]

    def body(carry, xs):
        x = carry
        p, cache = xs
        self_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
        x, new_self = apply_attn(p["attn"], cfg, x, positions, mode=mode,
                                 cache=self_cache, window=window)
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
        else:
            xk, xv = _cross_kv(cfg, p["xattn"], enc_out)
        x, _ = apply_attn(p["xattn"], cfg, x, positions, mode="cross",
                          xattn_kv=(xk, xv))
        x = apply_dense_ffn(p["ffn"], x, cfg.norm_eps)
        if new_self is None:
            out = 0.0
        else:
            out = {"k": new_self["k"], "v": new_self["v"], "xk": xk, "xv": xv}
        return x, out

    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], caches),
                                 unroll=unroll)
    return x, new_caches


def encdec_loss(cfg: ModelConfig, params, batch, *, remat=True,
                unroll=False) -> jax.Array:
    frames, tokens = batch["frontend"], batch["tokens"]
    enc_out = _encode(cfg, params, frames, unroll=unroll)
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = jnp.take(params["dec_embed"], tokens, axis=0)
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    x, _ = _decode_stack(cfg, params, x, pos, enc_out, mode="train",
                         unroll=unroll)
    logits = (rms_norm(x, params["dec_ln"], cfg.norm_eps)
              @ params["lm_head"]).astype(jnp.float32)
    pred, tgt = logits[:, :-1], tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def init_encdec_cache(cfg: ModelConfig, batch: int, length: int,
                      n_frames: int = N_AUDIO_FRAMES):
    L = cfg.n_layers
    kv = init_kv_cache(cfg, batch, length)
    dt = jnp.dtype(cfg.dtype)
    one = {
        "k": kv["k"], "v": kv["v"],
        "xk": jnp.zeros((batch, n_frames, cfg.n_kv_heads, cfg.hd), dt),
        "xv": jnp.zeros((batch, n_frames, cfg.n_kv_heads, cfg.hd), dt),
    }
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (L,) + l.shape), one)


def encdec_cache_specs(cfg: ModelConfig):
    kv = P(None, "data", None, "model", None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv}


def encdec_prefill(cfg: ModelConfig, params, tokens, frames, window: int = 0,
                   unroll=False):
    enc_out = _encode(cfg, params, frames, unroll=unroll)
    B, S = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = jnp.take(params["dec_embed"], tokens, axis=0)
    x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    x, caches = _decode_stack(cfg, params, x, pos, enc_out, mode="prefill",
                              window=window, unroll=unroll)
    logits = rms_norm(x[:, -1:], params["dec_ln"], cfg.norm_eps) @ params["lm_head"]
    return logits, caches


def encdec_decode_step(cfg: ModelConfig, params, caches, token, pos, *,
                       window: int = 0, unroll=False):
    B = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    x = jnp.take(params["dec_embed"], token, axis=0)
    x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)
    x, new_caches = _decode_stack(cfg, params, x, positions, None,
                                  mode="decode", caches=caches, window=window,
                                  unroll=unroll)
    logits = rms_norm(x, params["dec_ln"], cfg.norm_eps) @ params["lm_head"]
    return logits, new_caches
