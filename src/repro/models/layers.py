"""Shared neural building blocks (pure-JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm", "rope", "dense_init", "swiglu", "gelu_mlp",
    "init_dense_ffn", "apply_dense_ffn",
]


def dense_init(key, shape, in_axis=0, dtype=jnp.float32, scale=1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = scale / np.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]   # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# FFN (dense)
# --------------------------------------------------------------------------

def init_dense_ffn(key, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "ln": jnp.zeros((d_model,), dtype),
        "w_up": dense_init(ks[0], (d_model, d_ff), 0, dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), 0, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), 0, dtype)
    return p


def swiglu(x, w_gate, w_up, w_down):
    g = jax.nn.silu(x @ w_gate)
    return (g * (x @ w_up)) @ w_down


def gelu_mlp(x, w_up, w_down):
    return jax.nn.gelu(x @ w_up) @ w_down


def apply_dense_ffn(p, x, eps: float):
    h = rms_norm(x, p["ln"], eps)
    if "w_gate" in p:
        return x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    return x + gelu_mlp(h, p["w_up"], p["w_down"])
