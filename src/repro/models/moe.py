"""Mixture-of-Experts FFN: top-k router, capacity-based sort dispatch,
optional shared experts (DeepSeekMoE-style fine-grained configuration).

Dispatch is gather/scatter-based (static shapes, no (T, E, C) one-hot tensor)
so that compiled FLOPs ≈ active FLOPs — this is what makes the MoE rooflines
honest.  Experts are sharded over the 'model' mesh axis (expert parallelism);
GSPMD inserts the dispatch all-to-alls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm, swiglu

__all__ = ["init_moe", "apply_moe", "set_moe_mesh", "EXPERT_LEAF_PATTERNS",
           "expert_group_spec"]

# pytree-path patterns of the per-expert weights (leading expert dim E,
# sharded over the expert-parallel axis).  The router, the MoE layernorm
# and the shared experts are replicated and gossip with the dense group —
# "moe|w_gate" does NOT match "moe|shared|w_gate".
EXPERT_LEAF_PATTERNS = ("moe|w_gate", "moe|w_up", "moe|w_down")


def expert_group_spec(gossip_every: int = 0, wire: str = "f32",
                      schedule: str = ""):
    """Policy-group spec for the expert weights (DESIGN §12).

    Expert-parallel fleets keep expert shards resident per pod — the
    default ``gossip_every=0`` opts them out of gossip entirely (each
    pod's experts specialize on its data); ``gossip_every=k`` slow-cycles
    them instead, optionally at a cheaper ``wire`` format or on their own
    ``schedule``.  Pass through ``RunConfig.gossip_groups="moe[:k]"``.
    """
    from repro.core.bus import GroupSpec
    return GroupSpec("experts", EXPERT_LEAF_PATTERNS,
                     gossip_every=gossip_every, wire=wire, schedule=schedule)

# §Perf lever: when a mesh is registered, the dispatch/combine buffers get
# explicit sharding constraints; with impl="shard_map" the whole MoE FFN runs
# as a manually-sharded layer (expert-local dispatch + one activation psum —
# see apply_moe_shard_map).  Enabled by the dry-run / launcher via
# ``set_moe_mesh(mesh, impl=...)``; None = let GSPMD decide.
_MESH = {"mesh": None, "impl": "gspmd"}


def set_moe_mesh(mesh, impl: str = "gspmd") -> None:
    _MESH["mesh"] = mesh
    _MESH["impl"] = impl


def _constrain(x, *spec):
    mesh = _MESH["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))


def init_moe(key, cfg) -> Dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "ln": jnp.zeros((d,), dt),
        "router": dense_init(ks[0], (d, E), 0, jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, ff), 1, dt),
        "w_up": dense_init(ks[2], (E, d, ff), 1, dt),
        "w_down": dense_init(ks[3], (E, ff, d), 1, dt),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ff
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, sff), 0, dt),
            "w_up": dense_init(ks[5], (d, sff), 0, dt),
            "w_down": dense_init(jax.random.fold_in(key, 7), (sff, d), 0, dt),
        }
    return p


def _route(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing with softmax-renormalized weights.

    Returns (weights (T,k) f32, expert_idx (T,k) i32, aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.clip(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    E = logits.shape[-1]
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
    prob_density = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * prob_density)
    return w, idx, aux


def _dispatch_compute_combine(flat, w, idx, keep_extra, wg, wu, wd, C):
    """Sort-based capacity dispatch + expert FFN + weighted combine.

    flat: (T, d); w/idx: (T, k) routing; keep_extra: (T*k,) ownership mask
    (True = this shard serves the assignment); experts wg/wu/wd: (E_l, d, f).
    Returns (T, d) combined output (zeros at unserved assignments)."""
    T, d = flat.shape
    E_l = wg.shape[0]
    k = idx.shape[1]
    e_flat = idx.reshape(-1)
    w_flat = w.reshape(-1)
    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = order // k
    own_sorted = keep_extra[order]
    counts = jnp.bincount(jnp.where(keep_extra, e_flat, E_l), length=E_l + 1)
    starts = jnp.cumsum(counts) - counts
    # rank within owned assignments of each expert
    owned_before = jnp.cumsum(own_sorted.astype(jnp.int32)) - own_sorted
    rank = owned_before - starts[jnp.clip(e_sorted, 0, E_l)]
    keep = own_sorted & (rank < C) & (e_sorted < E_l)
    slot = jnp.clip(e_sorted, 0, E_l - 1) * C + jnp.clip(rank, 0, C - 1)

    buf = jnp.zeros((E_l * C, d), flat.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], flat[tok_sorted], 0))
    buf = buf.reshape(E_l, C, d)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, wd).reshape(E_l * C, d)
    gathered = out_buf[slot] * (w_flat[order] * keep)[:, None].astype(flat.dtype)
    return jnp.zeros((T, d), flat.dtype).at[tok_sorted].add(gathered)


def apply_moe_shard_map(p: Dict, cfg, x: jax.Array, eps: float, mesh):
    """Manually-sharded MoE FFN (§Perf, serving path).

    Insight: in our TP scheme the FFN input is replicated across the 'model'
    axis, so each model shard already holds every token of its data shard —
    dispatch to the shard's *own* E/16 experts is purely local, and the
    combine is ONE activation-sized psum over 'model' (identical cost to a
    dense row-parallel FFN).  No dispatch all-reduce, no all-to-all.
    """
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    h = rms_norm(x, p["ln"], eps)
    flat = h.reshape(T, d)
    dp = 1
    if "data" in mesh.axis_names:
        dp = mesh.devices.shape[mesh.axis_names.index("data")]
    use_dp = dp > 1 and T % dp == 0
    T_l = T // dp if use_dp else T
    C = max(8, int(cfg.capacity_factor * T_l * k / E))

    def body(flat_l, router, wg, wu, wd):
        E_l = wg.shape[0]
        shard = jax.lax.axis_index("model")
        w, idx, aux = _route(flat_l @ router.astype(flat_l.dtype), k)
        # ownership: assignment handled here iff its expert lives on this shard
        e_flat = idx.reshape(-1)
        local = (e_flat >= shard * E_l) & (e_flat < (shard + 1) * E_l)
        idx_local = jnp.where(local.reshape(idx.shape), idx - shard * E_l, E_l)
        out = _dispatch_compute_combine(flat_l, w, idx_local, local, wg, wu,
                                        wd, C)
        out = jax.lax.psum(out, "model")
        if use_dp:
            aux = jax.lax.pmean(aux, "data")
        return out, aux

    specs_w = P("model", None, None)
    d_ax = "data" if use_dp else None
    out_flat, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(d_ax, None), P(None, None), specs_w, specs_w, specs_w),
        out_specs=(P(d_ax, None), P()),
    )(flat, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    y = out_flat.reshape(B, S, d)
    if "shared" in p:
        sp = p["shared"]
        y = y + swiglu(h, sp["w_gate"], sp["w_up"], sp["w_down"])
    return x + y, cfg.router_aux_coef * aux


def apply_moe(p: Dict, cfg, x: jax.Array, eps: float) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (B, S, d), aux_loss.

    Capacity-based dispatch:  T*k assignments are sorted by expert id,
    ranked within each expert, and tokens beyond capacity C are dropped
    (their combine weight is zeroed) — Switch/GShard semantics.
    """
    if _MESH["impl"] == "shard_map" and _MESH["mesh"] is not None:
        return apply_moe_shard_map(p, cfg, x, eps, _MESH["mesh"])
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    C = max(8, int(cfg.capacity_factor * T * k / E))
    h = rms_norm(x, p["ln"], eps)
    flat = h.reshape(T, d)

    w, idx, aux = _route(flat @ p["router"].astype(flat.dtype), k)

    # ---- dispatch --------------------------------------------------------
    e_flat = idx.reshape(-1)                       # (T*k,) expert ids
    w_flat = w.reshape(-1)
    order = jnp.argsort(e_flat)                    # stable ascending experts
    e_sorted = e_flat[order]
    tok_sorted = order // k                        # source token of each slot
    # rank within expert: position among same-expert entries
    counts = jnp.bincount(e_flat, length=E)       # tokens per expert
    starts = jnp.cumsum(counts) - counts           # offset of each expert group
    rank = jnp.arange(T * k) - starts[e_sorted]
    keep = rank < C
    slot = e_sorted * C + jnp.clip(rank, 0, C - 1)  # (T*k,) buffer slot

    buf = jnp.zeros((E * C, d), flat.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], flat[tok_sorted], 0))
    buf = _constrain(buf.reshape(E, C, d), "model", None, None)

    # ---- expert compute (batched over E; sharded over 'model') -----------
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    out_buf = _constrain(out_buf, "model", None, None).reshape(E * C, d)

    # ---- combine ---------------------------------------------------------
    gathered = out_buf[slot] * (w_flat[order] * keep)[:, None].astype(flat.dtype)
    combined = jnp.zeros((T, d), flat.dtype).at[tok_sorted].add(gathered)
    combined = _constrain(combined, "data", None)

    y = combined.reshape(B, S, d)
    if "shared" in p:
        sp = p["shared"]
        y = y + swiglu(h, sp["w_gate"], sp["w_up"], sp["w_down"])
    return x + y, cfg.router_aux_coef * aux
