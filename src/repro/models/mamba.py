"""Mamba-1 selective-state-space block (falcon-mamba / jamba mixer).

TPU adaptation (DESIGN §2): instead of the CUDA fused selective-scan kernel we
use a **chunked associative scan** — within a chunk of ``chunk`` steps the
recurrence h_t = a_t ⊙ h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` (log-depth, MXU-friendly), and a short
``lax.scan`` over the S/chunk chunk boundaries threads the carry.  Working set
per chunk = (B, chunk, d_inner, d_state) in VMEM-sized tiles; the full
(B, S, d_inner, d_state) tensor is never materialized across the whole
sequence at once inside a chunk granularity larger than ``chunk``.

Decode is the O(1)-state single-step recurrence — the reason SSM archs run
``long_500k`` natively.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

__all__ = ["init_mamba", "apply_mamba", "init_ssm_cache", "ssm_scan_ref",
           "SSM_STATE_LEAF_PATTERNS", "ssm_state_group_spec"]

# pytree-path patterns of the conv/SSM state-dynamics leaves: the causal
# conv stencil and the per-channel state matrices (A_log/D/dt_bias) are
# tiny, sensitive recurrence parameters — the projections (in/x/dt/out)
# stay in the dense gossip group.
SSM_STATE_LEAF_PATTERNS = ("ssm|conv_w", "ssm|conv_b", "ssm|A_log",
                           "ssm|D", "ssm|dt_bias")


def ssm_state_group_spec(gossip_every: int = 0, wire: str = "f32",
                         schedule: str = ""):
    """Policy-group spec for the conv/SSM state leaves (DESIGN §12).

    Default ``gossip_every=0`` keeps them local-only (each agent's
    recurrence dynamics track its own data distribution — averaging
    S4D-initialized A_log across agents mid-training perturbs every
    channel's time constant); ``gossip_every=k`` slow-cycles them.  Pass
    through ``RunConfig.gossip_groups="ssm[:k]"``.
    """
    from repro.core.bus import GroupSpec
    return GroupSpec("ssm_state", SSM_STATE_LEAF_PATTERNS,
                     gossip_every=gossip_every, wire=wire, schedule=schedule)


def init_mamba(key, cfg) -> Dict:
    d, di, s, r, cw = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                       cfg.ssm_conv)
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, s + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "ln": jnp.zeros((d,), dt),
        "in_proj": dense_init(ks[0], (d, 2 * di), 0, dt),
        "conv_w": dense_init(ks[1], (cw, di), 0, dt, scale=1.0),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, r + 2 * s), 0, dt),
        "dt_proj": dense_init(ks[3], (r, di), 0, jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), 0, dt),
    }


def init_ssm_cache(cfg, batch: int, dtype=None):
    dt = dtype or jnp.float32
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dt),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv over seq.  x: (B, S, di); w: (cw, di)."""
    cw = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+cw-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return out + b, new_state


def ssm_scan_ref(a, b, h0):
    """Oracle: plain sequential scan of h_t = a_t*h_{t-1} + b_t.
    a, b: (B, S, di, s) f32;  h0: (B, di, s).  Returns (hs (B,S,di,s), h_T)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    (a_t, b_t) = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0))
    hT, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1), hT


def _chunked_scan(a, b, h0, chunk: int):
    """Chunked associative scan (see module docstring).
    a, b: (B, S, di, s); h0: (B, di, s) → (hs, h_T)."""
    B, S, di, s = a.shape
    if S % chunk:
        chunk = S  # fall back (smoke shapes)
    nc = S // chunk
    a_c = a.reshape(B, nc, chunk, di, s)
    b_c = b.reshape(B, nc, chunk, di, s)

    def combine(lhs, rhs):
        (al, bl), (ar, br) = lhs, rhs
        return al * ar, ar * bl + br

    # within-chunk prefix (assumes zero incoming state)
    a_pref, h_pref = jax.lax.associative_scan(combine, (a_c, b_c), axis=2)

    # thread the carry across chunks: h_in(next) = a_prod * h_in + h_last
    a_prod = a_pref[:, :, -1]           # (B, nc, di, s) cumprod of a per chunk
    h_last = h_pref[:, :, -1]

    def carry_step(h_in, xs):
        ap, hl = xs
        h_out = ap * h_in + hl
        return h_out, h_in
    (_, h_ins) = jax.lax.scan(
        carry_step, h0,
        (jnp.moveaxis(a_prod, 1, 0), jnp.moveaxis(h_last, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)   # (B, nc, di, s) incoming state per chunk

    hs = h_pref + a_pref * h_ins[:, :, None]
    h_T = hs[:, -1, -1]
    return hs.reshape(B, S, di, s), h_T


def apply_mamba(p: Dict, cfg, x: jax.Array, *, mode: str = "train",
                cache: Optional[Dict] = None, chunk: int = 256) -> Tuple:
    """Mamba block with pre-norm + residual.  Returns (y, new_cache)."""
    resid = x
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    B, S, d = h.shape
    di, s, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank

    xz = h @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di) each

    conv_state = cache["conv"] if cache is not None else None
    xr, new_conv = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_state)
    xr = jax.nn.silu(xr)

    proj = xr @ p["x_proj"]                               # (B, S, r+2s)
    dt_r, Bc, Cc = jnp.split(proj, [r, r + s], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])                  # (B, S, di)
    A = -jnp.exp(p["A_log"])                              # (di, s)
    a = jnp.exp(dt[..., None] * A)                        # (B, S, di, s)
    bx = (dt * xr.astype(jnp.float32))[..., None] * \
        Bc.astype(jnp.float32)[..., None, :]              # (B, S, di, s)

    h0 = cache["h"] if cache is not None else jnp.zeros((B, di, s), jnp.float32)
    if mode == "decode":
        # S == 1 single-step recurrence
        h_new = a[:, 0] * h0 + bx[:, 0]                   # (B, di, s)
        y = jnp.einsum("bds,bs->bd", h_new, Cc[:, 0].astype(jnp.float32))
        y = y[:, None]                                    # (B, 1, di)
        hT = h_new
    else:
        hs, hT = _chunked_scan(a, bx, h0, chunk)
        y = jnp.einsum("btds,bts->btd", hs, Cc.astype(jnp.float32))
    y = y + p["D"] * xr.astype(jnp.float32)
    y = y.astype(h.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": hT, "conv": new_conv.astype(cache["conv"].dtype)}
    return resid + out, new_cache
