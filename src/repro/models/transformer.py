"""Unified decoder LM covering dense / MoE / SSM / hybrid / VLM families.

Layers are grouped into **period blocks** (configs.base.block_period): all
layers at the same position-within-period share a stacked parameter tree of
leading dim ``n_blocks`` and the stack is driven by ``jax.lax.scan`` — this
keeps the HLO size O(period) instead of O(n_layers), which is what makes the
94-layer MoE dry-run compile in seconds.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, block_period, layer_kinds
from .attention import (apply_attn, apply_attn_paged,
                        apply_attn_paged_prefill, init_attn, init_kv_cache)
from .layers import apply_dense_ffn, dense_init, init_dense_ffn, rms_norm
from .mamba import apply_mamba, init_mamba, init_ssm_cache
from .moe import apply_moe, init_moe

__all__ = [
    "init_lm", "lm_loss", "lm_prefill", "lm_decode_step",
    "lm_decode_step_paged", "lm_prefill_chunk_paged", "lm_serve_step_mixed",
    "init_lm_cache", "lm_param_specs", "lm_cache_specs",
    "set_seq_parallel_mesh",
]

# §Perf lever (Megatron-style sequence parallelism): constrain the residual
# stream between layers to be sequence-sharded over 'model', turning each TP
# all-reduce (2× payload) into reduce-scatter + all-gather (1× payload).
_SEQ_PAR = {"mesh": None}


def set_seq_parallel_mesh(mesh) -> None:
    _SEQ_PAR["mesh"] = mesh


# §Perf lever (ZeRO-3 / agents="pod" mode): re-constrain each layer's weight
# slice to its FSDP sharding INSIDE the scan body, so XLA all-gathers one
# layer at a time instead of materializing the whole unsharded stack.
_FSDP = {"mesh": None, "specs": None}


def set_fsdp_constraint(mesh, specs) -> None:
    _FSDP["mesh"] = mesh
    _FSDP["specs"] = specs


def _fsdp_constrain(tree, pi):
    mesh = _FSDP["mesh"]
    if mesh is None:
        return tree
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, sp: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, sp)),
        tree, _FSDP["specs"][pi], is_leaf=lambda v: isinstance(v, P))


def _seq_constrain(x):
    mesh = _SEQ_PAR["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(None, "model", None)))


# ---------------------------------------------------------------------------
# per-layer init / apply / spec
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, kind, key) -> Dict:
    mixer, ffn = kind
    k1, k2 = jax.random.split(key)
    p = {}
    if mixer == "attn":
        p["attn"] = init_attn(k1, cfg)
    else:
        p["ssm"] = init_mamba(k1, cfg)
    if ffn == "dense":
        ff = cfg.dense_d_ff or cfg.d_ff
        p["ffn"] = init_dense_ffn(k2, cfg.d_model, ff, cfg.mlp_gated,
                                  jnp.dtype(cfg.dtype))
    elif ffn == "moe":
        p["moe"] = init_moe(k2, cfg)
    return p


def _apply_layer(cfg, kind, p, x, positions, *, mode, cache, window, aux):
    mixer, ffn = kind
    new_cache = None
    if mixer == "attn":
        x, new_cache = apply_attn(p["attn"], cfg, x, positions, mode=mode,
                                  cache=cache, window=window)
    else:
        m_mode = "decode" if mode == "decode" else "train"
        x, new_cache = apply_mamba(p["ssm"], cfg, x, mode=m_mode, cache=cache)
    if ffn == "dense":
        x = apply_dense_ffn(p["ffn"], x, cfg.norm_eps)
    elif ffn == "moe":
        x, a = apply_moe(p["moe"], cfg, x, cfg.norm_eps)
        aux = aux + a
    return x, new_cache, aux


def _attn_specs(cfg) -> Dict:
    sp = {
        "ln": P(None),
        "wq": P(None, "model"), "wk": P(None, "model"), "wv": P(None, "model"),
        "wo": P("model", None),
    }
    if cfg.qkv_bias:
        sp.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
    if cfg.qk_norm:
        sp.update({"q_norm": P(None), "k_norm": P(None)})
    return sp


def _ssm_specs(cfg) -> Dict:
    return {
        "ln": P(None),
        "in_proj": P(None, "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "x_proj": P("model", None),
        "dt_proj": P(None, "model"), "dt_bias": P("model"),
        "A_log": P("model", None), "D": P("model"),
        "out_proj": P("model", None),
    }


def _ffn_specs(cfg, gated) -> Dict:
    sp = {"ln": P(None), "w_up": P(None, "model"), "w_down": P("model", None)}
    if gated:
        sp["w_gate"] = P(None, "model")
    return sp


def _moe_specs(cfg) -> Dict:
    sp = {
        "ln": P(None),
        "router": P(None, None),
        # expert parallelism: experts sharded over the 'model' axis
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if cfg.n_shared_experts:
        sp["shared"] = _ffn_specs(cfg, True)
        del sp["shared"]["ln"]
    return sp


def _layer_specs(cfg, kind) -> Dict:
    mixer, ffn = kind
    sp = {}
    if mixer == "attn":
        sp["attn"] = _attn_specs(cfg)
    else:
        sp["ssm"] = _ssm_specs(cfg)
    if ffn == "dense":
        sp["ffn"] = _ffn_specs(cfg, cfg.mlp_gated)
    elif ffn == "moe":
        sp["moe"] = _moe_specs(cfg)
    return sp


def _prepend(spec: P, axis) -> P:
    return P(axis, *tuple(spec))


# ---------------------------------------------------------------------------
# model init / specs
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key) -> Dict:
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    n_blocks = cfg.n_layers // period
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, period + 3)
    blocks = []
    for pi, kind in enumerate(kinds):
        bkeys = jax.random.split(keys[pi], n_blocks)
        blocks.append(jax.vmap(functools.partial(_init_layer, cfg, kind))(bkeys))
    return {
        "embed": dense_init(keys[-3], (cfg.vocab_size, cfg.d_model), 1, dt),
        "blocks": tuple(blocks),
        "final_ln": jnp.zeros((cfg.d_model,), dt),
        "lm_head": dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), 0, dt),
    }


def lm_param_specs(cfg: ModelConfig) -> Dict:
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    blocks = []
    for kind in kinds:
        sp = _layer_specs(cfg, kind)
        blocks.append(jax.tree.map(
            lambda s: _prepend(s, None), sp,
            is_leaf=lambda s: isinstance(s, P)))
    return {
        "embed": P("model", None),
        "blocks": tuple(blocks),
        "final_ln": P(None),
        "lm_head": P(None, "model"),
    }


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, tokens, frontend_embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return x


def _stack_scan(cfg, params, x, positions, *, mode, caches=None, window=0,
                remat=True, unroll=False, remat_policy="full"):
    """Scan the period-block stack.  Returns (x, new_caches, aux)."""
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]

    def body(carry, xs):
        x, aux = carry
        block_params, block_caches = xs
        new_caches = []
        for pi, kind in enumerate(kinds):
            c = None if block_caches is None else block_caches[pi]
            x = _seq_constrain(x)
            bp = _fsdp_constrain(block_params[pi], pi)
            x, nc, aux = _apply_layer(cfg, kind, bp, x, positions,
                                      mode=mode, cache=c, window=window, aux=aux)
            new_caches.append(nc if nc is not None else 0.0)
        return (x, aux), tuple(new_caches)

    if remat and mode == "train":
        if remat_policy == "dots":
            # save matmul (incl. TP-all-reduced) outputs; recompute only
            # elementwise ops — no collective recompute in backward (§Perf)
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_saveable)
        else:
            body = jax.checkpoint(body)

    xs = (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs, unroll=unroll)
    return x, new_caches, aux


def _logits(cfg, params, x):
    h = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return h @ params["lm_head"]


def lm_loss(cfg: ModelConfig, params, batch, *, remat=True,
            unroll=False, remat_policy="full") -> jax.Array:
    """Next-token cross entropy.  batch: {tokens (B,S) int32,
    [frontend (B,P,d)]}; loss predicts tokens[1:] from prefix."""
    tokens = batch["tokens"]
    fe = batch.get("frontend")
    x = _embed_inputs(cfg, params, tokens, fe)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _, aux = _stack_scan(cfg, params, x, positions, mode="train",
                            remat=remat, unroll=unroll,
                            remat_policy=remat_policy)
    logits = _logits(cfg, params, x).astype(jnp.float32)
    # predict token t+1 at position t; frontend positions predict nothing.
    n_front = 0 if fe is None else fe.shape[1]
    pred = logits[:, n_front:-1]                       # (B, St-1, V)
    tgt = tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + aux


def init_lm_cache(cfg: ModelConfig, batch: int, length: int):
    """Cache pytree matching the block structure: tuple over period positions
    of stacked (n_blocks, ...) leaves."""
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    n_blocks = cfg.n_layers // period
    caches = []
    for mixer, _ in kinds:
        if mixer == "attn":
            one = init_kv_cache(cfg, batch, length)
        else:
            one = init_ssm_cache(cfg, batch)
        caches.append(jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_blocks,) + l.shape), one))
    return tuple(caches)


def lm_cache_specs(cfg: ModelConfig) -> Tuple:
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    specs = []
    for mixer, _ in kinds:
        if mixer == "attn":
            # (n_blocks, B, S, K, hd): batch over 'data', kv heads over 'model'
            one = {"k": P(None, "data", None, "model", None),
                   "v": P(None, "data", None, "model", None)}
        else:
            one = {"h": P(None, "data", "model", None),
                   "conv": P(None, "data", None, "model")}
        specs.append(one)
    return tuple(specs)


def lm_prefill(cfg: ModelConfig, params, tokens, frontend_embeds=None,
               window: int = 0, unroll=False):
    """Full-sequence forward returning (last-token logits, kv caches)."""
    x = _embed_inputs(cfg, params, tokens, frontend_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    n_blocks = cfg.n_layers // period
    # prefill needs per-layer caches as scan *outputs*; mamba still needs a
    # zero initial state, so pass explicit empty caches where required.
    caches = init_lm_cache(cfg, B, S if not window else window)
    x, new_caches, _ = _stack_scan(cfg, params, x, positions, mode="prefill",
                                   caches=caches, window=window, remat=False,
                                   unroll=unroll)
    logits = _logits(cfg, params, x[:, -1:])
    return logits, new_caches


def lm_decode_step(cfg: ModelConfig, params, caches, token, pos, *,
                   window: int = 0, unroll=False):
    """One decode step.  token: (B, 1) int32; pos: scalar int32 (uniform
    across the batch).  Returns (logits (B,1,V), new caches)."""
    x = jnp.take(params["embed"], token, axis=0)
    B = token.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    x, new_caches, _ = _stack_scan(cfg, params, x, positions, mode="decode",
                                   caches=caches, window=window, remat=False,
                                   unroll=unroll)
    return _logits(cfg, params, x), new_caches


def lm_decode_step_paged(cfg: ModelConfig, params, pools, token, positions,
                         page_table, kv_len, *, window: int = 0,
                         unroll=False, attn_fn=None):
    """One continuous-batching decode step over the whole slot batch
    (DESIGN §10).  Unlike :func:`lm_decode_step`, positions are **ragged**:
    ``positions`` is (B,) int32 — each slot's absolute token position —
    and ``kv_len`` is (B,) valid KV rows (0 for idle slots).  ``pools`` is
    the paged-cache tree (tuple over period positions of {"k","v"} pools
    with leading ``n_blocks``), scanned exactly like dense caches.
    ``attn_fn`` threads the attention backend down to
    :func:`~repro.models.attention.apply_attn_paged`.
    Returns (logits (B, 1, V), new pools)."""
    x = jnp.take(params["embed"], token, axis=0)
    B = token.shape[0]
    pos2 = positions.reshape(B, 1).astype(jnp.int32)
    period = block_period(cfg)
    kinds = layer_kinds(cfg)[:period]
    assert all(mixer == "attn" for mixer, _ in kinds), \
        "paged decode covers attention mixers only (DESIGN §10 scope note)"

    def body(carry, xs):
        x, aux = carry
        block_params, block_pools = xs
        new_pools = []
        for pi, (mixer, ffn) in enumerate(kinds):
            bp = _fsdp_constrain(block_params[pi], pi)
            x, npools = apply_attn_paged(
                bp["attn"], cfg, x, pos2, pools=block_pools[pi],
                page_table=page_table, kv_len=kv_len, window=window,
                attn_fn=attn_fn)
            if ffn == "dense":
                x = apply_dense_ffn(bp["ffn"], x, cfg.norm_eps)
            elif ffn == "moe":
                x, a = apply_moe(bp["moe"], cfg, x, cfg.norm_eps)
                aux = aux + a
            new_pools.append(npools)
        return (x, aux), tuple(new_pools)

    (x, _), new_pools = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], pools),
        unroll=unroll)
    return _logits(cfg, params, x), new_pools


def _check_attn_only(cfg):
    kinds = layer_kinds(cfg)[:block_period(cfg)]
    assert all(mixer == "attn" for mixer, _ in kinds), \
        "paged serving covers attention mixers only (DESIGN §10 scope note)"
    return kinds


def lm_prefill_chunk_paged(cfg: ModelConfig, params, pools, tokens, pt_row,
                           chunk_start, chunk_len, *, window: int = 0,
                           unroll=False, attn_fn=None):
    """One chunked-prefill step for ONE slot (DESIGN §11): run a fixed-size
    chunk of the slot's prompt through the stack, attending to the slot's
    previously-filled pages, and scatter the chunk's K/V into its pages.

    tokens: (1, C) int32 — the chunk, padded to the static width C;
    pt_row: (n_pages,) the slot's page-table row; chunk_start / chunk_len:
    traced int32 scalars (cursor and valid-token count).  ``attn_fn``
    selects the Pallas paged-prefill kernel (see
    :func:`~repro.models.attention.apply_attn_paged_prefill`).
    Returns (logits (1, C, V), new pools) — logits rows ≥ chunk_len are
    padding garbage the caller must ignore."""
    kinds = _check_attn_only(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)

    def body(carry, xs):
        x, aux = carry
        block_params, block_pools = xs
        new_pools = []
        for pi, (mixer, ffn) in enumerate(kinds):
            bp = _fsdp_constrain(block_params[pi], pi)
            x, npools = apply_attn_paged_prefill(
                bp["attn"], cfg, x, pools=block_pools[pi], pt_row=pt_row,
                chunk_start=chunk_start, chunk_len=chunk_len, window=window,
                attn_fn=attn_fn)
            if ffn == "dense":
                x = apply_dense_ffn(bp["ffn"], x, cfg.norm_eps)
            elif ffn == "moe":
                x, a = apply_moe(bp["moe"], cfg, x, cfg.norm_eps)
                aux = aux + a
            new_pools.append(npools)
        return (x, aux), tuple(new_pools)

    (x, _), new_pools = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], pools),
        unroll=unroll)
    return _logits(cfg, params, x), new_pools


def lm_serve_step_mixed(cfg: ModelConfig, params, pools, token, positions,
                        page_table, kv_len, chunk_tokens, pt_row,
                        chunk_start, chunk_len, *, window: int = 0,
                        unroll=False, attn_fn=None, prefill_attn_fn=None):
    """The fused mixed-work serving step (DESIGN §11): every live decode
    slot advances one token AND one prefill chunk of one mid-prefill slot
    runs, inside a SINGLE weight scan — the chunk piggybacks on the
    weights the decode batch already pulled through VMEM, which is the
    whole point of chunked prefill (no separate prompt pass, no
    head-of-line blocking).

    Decode inputs are exactly :func:`lm_decode_step_paged`'s (the engine
    masks mid-prefill slots out of ``page_table``/``kv_len`` — their ring
    rows are live); chunk inputs are exactly
    :func:`lm_prefill_chunk_paged`'s.  Within a layer the decode batch
    runs first, then the chunk — their page writes are disjoint (the
    chunk's slot is masked out of the decode dispatch, so its decode-side
    write sinks to the null page).

    Returns (decode logits (B, 1, V), chunk logits (1, C, V), new pools).
    """
    kinds = _check_attn_only(cfg)
    xd = jnp.take(params["embed"], token, axis=0)
    xc = jnp.take(params["embed"], chunk_tokens, axis=0)
    B = token.shape[0]
    pos2 = positions.reshape(B, 1).astype(jnp.int32)

    def body(carry, xs):
        xd, xc, aux = carry
        block_params, block_pools = xs
        new_pools = []
        for pi, (mixer, ffn) in enumerate(kinds):
            bp = _fsdp_constrain(block_params[pi], pi)
            xd, npools = apply_attn_paged(
                bp["attn"], cfg, xd, pos2, pools=block_pools[pi],
                page_table=page_table, kv_len=kv_len, window=window,
                attn_fn=attn_fn)
            xc, npools = apply_attn_paged_prefill(
                bp["attn"], cfg, xc, pools=npools, pt_row=pt_row,
                chunk_start=chunk_start, chunk_len=chunk_len, window=window,
                attn_fn=prefill_attn_fn)
            if ffn == "dense":
                xd = apply_dense_ffn(bp["ffn"], xd, cfg.norm_eps)
                xc = apply_dense_ffn(bp["ffn"], xc, cfg.norm_eps)
            elif ffn == "moe":
                xd, ad = apply_moe(bp["moe"], cfg, xd, cfg.norm_eps)
                xc, ac = apply_moe(bp["moe"], cfg, xc, cfg.norm_eps)
                aux = aux + ad + ac
            new_pools.append(npools)
        return (xd, xc, aux), tuple(new_pools)

    (xd, xc, _), new_pools = jax.lax.scan(
        body, (xd, xc, jnp.zeros((), jnp.float32)),
        (params["blocks"], pools), unroll=unroll)
    return _logits(cfg, params, xd), _logits(cfg, params, xc), new_pools
