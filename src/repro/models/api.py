"""Public model API: build any assigned architecture behind one interface.

``Model`` bundles init / loss / prefill / decode / specs.  ``input_specs``
returns ``jax.ShapeDtypeStruct`` stand-ins for every model input of a given
run shape — the dry-run lowers against these (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from . import encdec as ed
from . import transformer as tf

__all__ = ["Model", "build_model", "input_specs", "batch_specs"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable                    # key -> params
    loss: Callable                    # (params, batch) -> scalar
    prefill: Callable                 # (params, batch) -> (logits, caches)
    decode_step: Callable             # (params, caches, token, pos) -> (logits, caches)
    init_cache: Callable              # (batch, length) -> caches
    param_specs: Callable             # () -> pytree of PartitionSpec
    cache_specs: Callable             # () -> pytree of PartitionSpec
    decode_window: int = 0            # sliding-window size baked at build time
    # (params, pools, token, positions, page_table, kv_len, attn_fn=None)
    # -> (logits, pools); None for families without a paged decode path
    decode_step_paged: Optional[Callable] = None
    # (params, pools, tokens, pt_row, chunk_start, chunk_len, attn_fn=None)
    # -> (chunk logits, pools); one prompt chunk of one slot (DESIGN §11)
    prefill_chunk_paged: Optional[Callable] = None
    # (params, pools, token, positions, page_table, kv_len, chunk_tokens,
    #  pt_row, chunk_start, chunk_len, attn_fn=None, prefill_attn_fn=None)
    # -> (decode logits, chunk logits, pools); the fused mixed serving step
    decode_step_mixed: Optional[Callable] = None


def _frontend_tokens(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.n_frontend_tokens
    return 0


def build_model(cfg: ModelConfig, decode_window: int = 0,
                unroll: bool = False) -> Model:
    if cfg.family == "encdec":
        def loss(params, batch, remat=True):
            return ed.encdec_loss(cfg, params, batch, remat=remat,
                                  unroll=unroll)

        def prefill(params, batch):
            return ed.encdec_prefill(cfg, params, batch["tokens"],
                                     batch["frontend"], window=decode_window,
                                     unroll=unroll)

        def decode_step(params, caches, token, pos):
            return ed.encdec_decode_step(cfg, params, caches, token, pos,
                                         window=decode_window, unroll=unroll)

        def init_cache(batch, length):
            return ed.init_encdec_cache(cfg, batch, length,
                                        n_frames=cfg.n_frontend_tokens)

        return Model(cfg, lambda k: ed.init_encdec(cfg, k), loss, prefill,
                     decode_step, init_cache,
                     lambda: ed.encdec_param_specs(cfg),
                     lambda: ed.encdec_cache_specs(cfg),
                     decode_window=decode_window)

    nf = _frontend_tokens(cfg)

    def loss(params, batch, remat=True, remat_policy="full"):
        return tf.lm_loss(cfg, params, batch, remat=remat, unroll=unroll,
                          remat_policy=remat_policy)

    def prefill(params, batch):
        return tf.lm_prefill(cfg, params, batch["tokens"],
                             batch.get("frontend"), window=decode_window,
                             unroll=unroll)

    def decode_step(params, caches, token, pos):
        return tf.lm_decode_step(cfg, params, caches, token, pos,
                                 window=decode_window, unroll=unroll)

    def init_cache(batch, length):
        return tf.init_lm_cache(cfg, batch, length)

    def decode_step_paged(params, pools, token, positions, page_table,
                          kv_len, attn_fn=None):
        return tf.lm_decode_step_paged(cfg, params, pools, token, positions,
                                       page_table, kv_len,
                                       window=decode_window, unroll=unroll,
                                       attn_fn=attn_fn)

    def prefill_chunk_paged(params, pools, tokens, pt_row, chunk_start,
                            chunk_len, attn_fn=None):
        return tf.lm_prefill_chunk_paged(cfg, params, pools, tokens, pt_row,
                                         chunk_start, chunk_len,
                                         window=decode_window, unroll=unroll,
                                         attn_fn=attn_fn)

    def decode_step_mixed(params, pools, token, positions, page_table,
                          kv_len, chunk_tokens, pt_row, chunk_start,
                          chunk_len, attn_fn=None, prefill_attn_fn=None):
        return tf.lm_serve_step_mixed(cfg, params, pools, token, positions,
                                      page_table, kv_len, chunk_tokens,
                                      pt_row, chunk_start, chunk_len,
                                      window=decode_window, unroll=unroll,
                                      attn_fn=attn_fn,
                                      prefill_attn_fn=prefill_attn_fn)

    return Model(cfg, lambda k: tf.init_lm(cfg, k), loss, prefill,
                 decode_step, init_cache,
                 lambda: tf.lm_param_specs(cfg),
                 lambda: tf.lm_cache_specs(cfg),
                 decode_window=decode_window,
                 decode_step_paged=decode_step_paged,
                 prefill_chunk_paged=prefill_chunk_paged,
                 decode_step_mixed=decode_step_mixed)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs (dry-run stand-ins, no allocation)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, run: RunConfig,
                agent_axis: Optional[int] = None) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs.

    agent_axis: if given, a leading per-agent axis A is prepended and the
    per-agent batch is global_batch // A (decentralized trainer layout).
    """
    B, S = run.global_batch, run.seq_len
    lead: tuple = ()
    if agent_axis:
        assert B % agent_axis == 0, (B, agent_axis)
        lead, B = (agent_axis,), B // agent_axis
    d = cfg.d_model
    fdt = jnp.dtype(cfg.dtype)
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct(lead + (B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["frontend"] = jax.ShapeDtypeStruct(
            lead + (B, cfg.n_frontend_tokens, d), fdt)
    elif cfg.family == "encdec":
        specs["frontend"] = jax.ShapeDtypeStruct(
            lead + (B, cfg.n_frontend_tokens, d), fdt)
    return specs


def input_specs(cfg: ModelConfig, run: RunConfig,
                agent_axis: Optional[int] = None) -> Dict[str, Any]:
    """Full input specs for the run mode (train/prefill: batch;
    decode: token + pos + caches)."""
    if run.mode in ("train", "prefill"):
        return batch_specs(cfg, run, agent_axis)
    # decode: one token with a seq_len-long context cache
    B = run.global_batch
    cache_len = run.decode_window or run.seq_len
    model = build_model(cfg, decode_window=run.decode_window)
    caches = jax.eval_shape(lambda: model.init_cache(B, cache_len))
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }
