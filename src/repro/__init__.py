"""repro — EDM (Exact-Diffusion with Momentum) production training framework.

Paper: "A Bias-Correction Decentralized Stochastic Gradient Algorithm with
Momentum Acceleration" (Hu, Chen, Liu & Mao, 2025).
"""
__version__ = "1.0.0"
