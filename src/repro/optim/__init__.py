"""repro.optim — step-size schedules for the decentralized trainer."""
from .schedules import (  # noqa: F401
    constant, cosine, linear_warmup, scale_grads, warmup_cosine,
)
