"""Step-size schedules for decentralized training.

The paper's theory uses a constant α = O(1-λ); production training needs
warmup + decay.  Schedules compose with any registered algorithm through
``scale_by_schedule`` — the optimizer is built with α=1 and the per-step
scale multiplies the *gradient* before the update, which for every algorithm
in repro.core.optimizers is equivalent to scaling α (they are all linear in
the gradient path) while keeping the bias-correction recursion intact.
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

__all__ = ["constant", "cosine", "linear_warmup", "warmup_cosine",
           "scale_grads"]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> multiplier


def constant(value: float = 1.0) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(warmup_steps: int, base: float = 1.0) -> Schedule:
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return base * jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
    return f


def cosine(total_steps: int, base: float = 1.0, floor: float = 0.1) -> Schedule:
    def f(step):
        s = jnp.clip(jnp.asarray(step, jnp.float32), 0, total_steps)
        cos = 0.5 * (1.0 + jnp.cos(math.pi * s / max(total_steps, 1)))
        return base * (floor + (1.0 - floor) * cos)
    return f


def warmup_cosine(warmup_steps: int, total_steps: int, base: float = 1.0,
                  floor: float = 0.1) -> Schedule:
    w = linear_warmup(warmup_steps, base)
    c = cosine(total_steps, base, floor)
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        return jnp.where(s < warmup_steps, w(step), c(step))
    return f


def scale_grads(grads, step, schedule: Schedule):
    """Multiply every gradient leaf by schedule(step)."""
    import jax
    m = schedule(step)
    return jax.tree.map(lambda g: (m * g.astype(jnp.float32)).astype(g.dtype),
                        grads)
