"""Pallas TPU kernel: fused EDM optimizer update (+ ring-gossip combine).

The EDM hot loop is memory-bound: the unfused jnp chain

    m'  = β m + (1-β) g
    ψ'  = x − α m'
    φ   = ψ' + x − ψ

reads x, g, m, ψ and writes m', ψ', φ as ~7 separate HBM-stream kernels
(XLA fuses some, but the optimizer-state round trip still dominates at
multi-billion-parameter scale).  This kernel performs the whole chain in one
pass over VMEM tiles: 4 reads + 3 writes = 7 HBM touches of N elements total,
the information-theoretic minimum.

``gossip_axpy`` fuses the post-permute combine  Σₖ wₖ·payloadₖ  (one payload
per gossip term — center/left/right in the ring case, more for exp graphs
and hierarchical topologies) into one pass — applied after the explicit
``ppermute``s of the production gossip engine (DESIGN §3).  n-ary, with a
bf16 payload path that accumulates in f32.

Layout: parameters are flattened and tiled to (rows, 128) f32; one grid step
processes a (BLOCK_ROWS, 128) tile — 8×128-aligned for the VPU, comfortably
inside the ~16 MB VMEM budget at the default 512×128×4 B×7 buffers ≈ 1.8 MB.

Two callers feed these kernels (kernels/ops.py): the per-leaf wrappers
(``edm_update`` / ``gossip_axpy``) pack each pytree leaf independently —
one pallas_call and one pad-to-grid per leaf — while the packed parameter
bus (``repro.core.bus``, DESIGN §5) presents the whole per-agent tree as a
single pre-aligned (rows, 128) buffer, so ``edm_update_bus`` runs the grid
exactly once per train step regardless of leaf count.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["edm_update_flat", "gossip_axpy_flat", "BLOCK_ROWS", "LANE"]

def _env_block_rows() -> int:
    """Grid-tile height: the knob the real-TPU tuning sweep turns.  Read
    once at import from REPRO_BLOCK_ROWS (benchmarks/gossip_micro.py
    --block-rows and the per-call ``block_rows=`` args override it); must
    be a multiple of 8 for the 8×128 VPU tile."""
    raw = os.environ.get("REPRO_BLOCK_ROWS", "")
    if not raw:
        return 512
    try:
        n = int(raw)
    except ValueError:
        raise ValueError(f"REPRO_BLOCK_ROWS must be an integer, got {raw!r}")
    if n <= 0 or n % 8:
        raise ValueError(
            f"REPRO_BLOCK_ROWS must be a positive multiple of 8, got {n}")
    return n


BLOCK_ROWS = _env_block_rows()
LANE = 128


def _edm_kernel(x_ref, g_ref, m_ref, psi_ref, m_out, psi_out, phi_out, *,
                alpha: float, beta: float):
    x = x_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    psi = psi_ref[...]
    m_new = beta * m + (1.0 - beta) * g
    psi_new = x - alpha * m_new
    phi = psi_new + x - psi
    m_out[...] = m_new
    psi_out[...] = psi_new
    phi_out[...] = phi


def edm_update_flat(x, g, m, psi, *, alpha: float, beta: float,
                    block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """All inputs: (rows, 128) f32 with rows % block_rows == 0.
    Returns (m_new, psi_new, phi)."""
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0, (x.shape, block_rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(
        functools.partial(_edm_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[out_sds] * 3,
        interpret=interpret,
    )(x, g, m, psi)


def _axpy_kernel(w_ref, *refs):
    # refs = (in_0, ..., in_{n-1}, out); w_ref = (1, n) weights in SMEM —
    # runtime values, so one compiled kernel serves every weight set of one
    # arity (time-varying schedules swap rounds without retracing).
    # Accumulate in f32 so a bf16 gossip payload only rounds once, on the
    # final store.
    o_ref = refs[-1]
    acc = w_ref[0, 0] * refs[0][...].astype(jnp.float32)
    for k, r in enumerate(refs[1:-1], start=1):
        acc += w_ref[0, k] * r[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def gossip_axpy_flat(operands, weights, *, block_rows: int | None = None,
                     interpret: bool = False):
    """Fused n-ary gossip combine  Σₖ wₖ·operandₖ  over (rows, 128) tiles.

    ``operands`` are the post-permute neighbor payloads of one gossip step
    (one per :class:`~repro.core.topology.ShiftTerm`); ``weights`` the
    matching mixing weights — floats or a traced (n,) array; they enter the
    kernel as an SMEM operand, so the compiled kernel is keyed on the
    *arity* n, not the weight values.  All operands share one shape/dtype
    (f32 or bf16); accumulation is f32, output dtype follows the operands.
    The ring case of the paper's experiments is the 3-ary instance
    (center/left/right).
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    operands = tuple(operands)
    w = jnp.asarray(weights, jnp.float32).reshape(1, -1)
    assert operands and w.shape[1] == len(operands), (len(operands), w.shape)
    rows, lane = operands[0].shape
    assert lane == LANE and rows % block_rows == 0, (operands[0].shape,
                                                     block_rows)
    assert all(o.shape == operands[0].shape and o.dtype == operands[0].dtype
               for o in operands)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _axpy_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)]
        + [spec] * len(operands),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(operands[0].shape, operands[0].dtype),
        interpret=interpret,
    )(w, *operands)
