"""Pallas TPU kernel: fused EDM optimizer update (+ ring-gossip combine).

The EDM hot loop is memory-bound: the unfused jnp chain

    m'  = β m + (1-β) g
    ψ'  = x − α m'
    φ   = ψ' + x − ψ

reads x, g, m, ψ and writes m', ψ', φ as ~7 separate HBM-stream kernels
(XLA fuses some, but the optimizer-state round trip still dominates at
multi-billion-parameter scale).  This kernel performs the whole chain in one
pass over VMEM tiles: 4 reads + 3 writes = 7 HBM touches of N elements total,
the information-theoretic minimum.

``gossip_axpy`` fuses the post-permute ring combine  w₀·c + w₁·l + w₂·r
(center/left/right neighbor payloads) into one pass — applied after the
collective-permutes that `jnp.roll` lowers to.

Layout: parameters are flattened and tiled to (rows, 128) f32; one grid step
processes a (BLOCK_ROWS, 128) tile — 8×128-aligned for the VPU, comfortably
inside the ~16 MB VMEM budget at the default 512×128×4 B×7 buffers ≈ 1.8 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["edm_update_flat", "gossip_axpy_flat", "BLOCK_ROWS", "LANE"]

BLOCK_ROWS = 512
LANE = 128


def _edm_kernel(x_ref, g_ref, m_ref, psi_ref, m_out, psi_out, phi_out, *,
                alpha: float, beta: float):
    x = x_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    psi = psi_ref[...]
    m_new = beta * m + (1.0 - beta) * g
    psi_new = x - alpha * m_new
    phi = psi_new + x - psi
    m_out[...] = m_new
    psi_out[...] = psi_new
    phi_out[...] = phi


def edm_update_flat(x, g, m, psi, *, alpha: float, beta: float,
                    block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """All inputs: (rows, 128) f32 with rows % block_rows == 0.
    Returns (m_new, psi_new, phi)."""
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0, (x.shape, block_rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(
        functools.partial(_edm_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[out_sds] * 3,
        interpret=interpret,
    )(x, g, m, psi)


def _axpy_kernel(c_ref, l_ref, r_ref, o_ref, *, w0: float, w1: float, w2: float):
    o_ref[...] = w0 * c_ref[...] + w1 * l_ref[...] + w2 * r_ref[...]


def gossip_axpy_flat(center, left, right, *, w0: float, w1: float, w2: float,
                     block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """Fused ring combine  w₀·center + w₁·left + w₂·right  over (rows, 128)."""
    rows, lane = center.shape
    assert lane == LANE and rows % block_rows == 0
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_axpy_kernel, w0=w0, w1=w1, w2=w2),
        grid=(rows // block_rows,),
        in_specs=[spec] * 3,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(center.shape, center.dtype),
        interpret=interpret,
    )(center, left, right)
