"""Pallas TPU kernel: fused EDM optimizer update (+ ring-gossip combine).

The EDM hot loop is memory-bound: the unfused jnp chain

    m'  = β m + (1-β) g
    ψ'  = x − α m'
    φ   = ψ' + x − ψ

reads x, g, m, ψ and writes m', ψ', φ as ~7 separate HBM-stream kernels
(XLA fuses some, but the optimizer-state round trip still dominates at
multi-billion-parameter scale).  This kernel performs the whole chain in one
pass over VMEM tiles: 4 reads + 3 writes = 7 HBM touches of N elements total,
the information-theoretic minimum.

``gossip_axpy`` fuses the post-permute combine  Σₖ wₖ·payloadₖ  (one payload
per gossip term — center/left/right in the ring case, more for exp graphs
and hierarchical topologies) into one pass — applied after the explicit
``ppermute``s of the production gossip engine (DESIGN §3).  n-ary, with a
bf16 payload path that accumulates in f32.

Layout: parameters are flattened and tiled to (rows, 128) f32; one grid step
processes a (BLOCK_ROWS, 128) tile — 8×128-aligned for the VPU, comfortably
inside the ~16 MB VMEM budget at the default 512×128×4 B×7 buffers ≈ 1.8 MB.

Two callers feed these kernels (kernels/ops.py): the per-leaf wrappers
(``edm_update`` / ``gossip_axpy``) pack each pytree leaf independently —
one pallas_call and one pad-to-grid per leaf — while the packed parameter
bus (``repro.core.bus``, DESIGN §5) presents the whole per-agent tree as a
single pre-aligned (rows, 128) buffer, so ``edm_update_bus`` runs the grid
exactly once per train step regardless of leaf count.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["edm_update_flat", "edm_update_ef_flat", "gossip_axpy_flat",
           "gossip_axpy_q8_flat", "BLOCK_ROWS", "LANE"]

def _env_block_rows() -> int:
    """Grid-tile height: the knob the real-TPU tuning sweep turns.  Read
    once at import from REPRO_BLOCK_ROWS (benchmarks/gossip_micro.py
    --block-rows and the per-call ``block_rows=`` args override it); must
    be a multiple of 8 for the 8×128 VPU tile."""
    raw = os.environ.get("REPRO_BLOCK_ROWS", "")
    if not raw:
        return 512
    try:
        n = int(raw)
    except ValueError:
        raise ValueError(f"REPRO_BLOCK_ROWS must be an integer, got {raw!r}")
    if n <= 0 or n % 8:
        raise ValueError(
            f"REPRO_BLOCK_ROWS must be a positive multiple of 8, got {n}")
    return n


BLOCK_ROWS = _env_block_rows()
LANE = 128


def _edm_kernel(x_ref, g_ref, m_ref, psi_ref, m_out, psi_out, phi_out, *,
                alpha: float, beta: float):
    x = x_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    psi = psi_ref[...]
    m_new = beta * m + (1.0 - beta) * g
    psi_new = x - alpha * m_new
    phi = psi_new + x - psi
    m_out[...] = m_new
    psi_out[...] = psi_new
    phi_out[...] = phi


def edm_update_flat(x, g, m, psi, *, alpha: float, beta: float,
                    block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """All inputs: (rows, 128) f32 with rows % block_rows == 0.
    Returns (m_new, psi_new, phi)."""
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0, (x.shape, block_rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_sds = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(
        functools.partial(_edm_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[out_sds] * 3,
        interpret=interpret,
    )(x, g, m, psi)


def _edm_ef_bf16_kernel(x_ref, g_ref, m_ref, psi_ref, e_ref,
                        m_out, psi_out, q_out, e_out, *,
                        alpha: float, beta: float):
    # EDM chain + error-feedback bf16 quantize in ONE pass: the corrected
    # payload c = φ + e rounds to bf16 on the wire, and the rounding error
    # stays behind as the next residual.  5 reads + 4 writes — no extra HBM
    # round trip vs the uncompressed kernel's 4+3 (e in, e out, φ→q swap).
    x = x_ref[...]
    m_new = beta * m_ref[...] + (1.0 - beta) * g_ref[...]
    psi_new = x - alpha * m_new
    c = psi_new + x - psi_ref[...] + e_ref[...]
    q = c.astype(jnp.bfloat16)
    m_out[...] = m_new
    psi_out[...] = psi_new
    q_out[...] = q
    e_out[...] = c - q.astype(jnp.float32)


def _edm_ef_int8_kernel(x_ref, g_ref, m_ref, psi_ref, e_ref,
                        m_out, psi_out, q_out, s_out, e_out, *,
                        alpha: float, beta: float):
    # int8 variant: the grid tile IS the scale block (block_rows, 128) — one
    # symmetric absmax scale per tile, written to a (1, 1) SMEM slot.  Guards
    # mirror core/wire.py: non-finite values are masked out of absmax, NaN
    # encodes to 0, ±Inf saturates to ±127; an all-zero tile (the bus pad
    # tail) gets scale 0 and q 0 — no 0/0.
    x = x_ref[...]
    m_new = beta * m_ref[...] + (1.0 - beta) * g_ref[...]
    psi_new = x - alpha * m_new
    c = psi_new + x - psi_ref[...] + e_ref[...]
    mag = jnp.where(jnp.isfinite(c), jnp.abs(c), 0.0)
    absmax = jnp.max(mag)
    scale = absmax / 127.0
    inv = jnp.where(absmax > 0.0, 127.0 / jnp.maximum(absmax, 1e-30), 0.0)
    q = jnp.clip(jnp.round(c * inv), -127.0, 127.0)
    q = jnp.where(jnp.isnan(c), 0.0, q)
    m_out[...] = m_new
    psi_out[...] = psi_new
    q_out[...] = q.astype(jnp.int8)
    s_out[0, 0] = scale
    e_out[...] = c - q * scale


def edm_update_ef_flat(x, g, m, psi, e, *, alpha: float, beta: float,
                       fmt: str, block_rows: int = BLOCK_ROWS,
                       interpret: bool = False):
    """Fused EDM + error-feedback quantize over (rows, 128) f32 buffers.

    Returns ``(m', ψ', q, e')`` for ``fmt="bf16"`` and
    ``(m', ψ', q, scale, e')`` for ``fmt="int8"`` with ``scale`` shaped
    ``(rows // block_rows, 1)`` f32 (one per grid tile, SMEM-written).
    ``fmt="f32"`` has no quantize to fuse — callers use
    :func:`edm_update_flat`.
    """
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0, (x.shape, block_rows)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    f32 = jax.ShapeDtypeStruct(x.shape, jnp.float32)
    kern = functools.partial(
        {"bf16": _edm_ef_bf16_kernel, "int8": _edm_ef_int8_kernel}[fmt],
        alpha=alpha, beta=beta)
    if fmt == "bf16":
        out_specs = [spec, spec, spec, spec]
        out_shape = [f32, f32,
                     jax.ShapeDtypeStruct(x.shape, jnp.bfloat16), f32]
    else:
        if not interpret:
            # int8 VMEM tiles are (32, 128) minimum on TPU.
            assert block_rows % 32 == 0, block_rows
        s_spec = pl.BlockSpec((1, 1), lambda i: (i, 0),
                              memory_space=pltpu.SMEM)
        out_specs = [spec, spec, spec, s_spec, spec]
        out_shape = [f32, f32,
                     jax.ShapeDtypeStruct(x.shape, jnp.int8),
                     jax.ShapeDtypeStruct((rows // block_rows, 1),
                                          jnp.float32), f32]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, g, m, psi, e)


def _axpy_kernel(w_ref, *refs):
    # refs = (in_0, ..., in_{n-1}, out); w_ref = (1, n) weights in SMEM —
    # runtime values, so one compiled kernel serves every weight set of one
    # arity (time-varying schedules swap rounds without retracing).
    # Accumulate in f32 so a bf16 gossip payload only rounds once, on the
    # final store.
    o_ref = refs[-1]
    acc = w_ref[0, 0] * refs[0][...].astype(jnp.float32)
    for k, r in enumerate(refs[1:-1], start=1):
        acc += w_ref[0, k] * r[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def gossip_axpy_flat(operands, weights, *, block_rows: int | None = None,
                     interpret: bool = False, out_dtype=None):
    """Fused n-ary gossip combine  Σₖ wₖ·operandₖ  over (rows, 128) tiles.

    ``operands`` are the post-permute neighbor payloads of one gossip step
    (one per :class:`~repro.core.topology.ShiftTerm`); ``weights`` the
    matching mixing weights — floats or a traced (n,) array; they enter the
    kernel as an SMEM operand, so the compiled kernel is keyed on the
    *arity* n, not the weight values.  All operands share one shape/dtype
    (f32 or bf16); accumulation is f32, output dtype follows the operands
    unless ``out_dtype`` overrides it (the wire-decode combine stores f32
    from bf16 payloads so the mixed iterate never re-rounds).  The ring
    case of the paper's experiments is the 3-ary instance
    (center/left/right).
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    operands = tuple(operands)
    w = jnp.asarray(weights, jnp.float32).reshape(1, -1)
    assert operands and w.shape[1] == len(operands), (len(operands), w.shape)
    rows, lane = operands[0].shape
    assert lane == LANE and rows % block_rows == 0, (operands[0].shape,
                                                     block_rows)
    assert all(o.shape == operands[0].shape and o.dtype == operands[0].dtype
               for o in operands)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    if out_dtype is None:
        out_dtype = operands[0].dtype
    return pl.pallas_call(
        _axpy_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)]
        + [spec] * len(operands),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(operands[0].shape, out_dtype),
        interpret=interpret,
    )(w, *operands)


def _axpy_q8_kernel(coef_ref, *refs):
    # refs = (q_0, ..., q_{n-1}, out); coef_ref = (n, n_tiles) f32 in SMEM
    # holding wₖ · scaleₖ[tile] — the wire decode is FOLDED into the
    # combine: int8 payloads widen to f32 exactly once, already weighted
    # and dequantized, and the mixed bus stores f32.
    o_ref = refs[-1]
    i = pl.program_id(0)
    acc = coef_ref[0, i] * refs[0][...].astype(jnp.float32)
    for k, r in enumerate(refs[1:-1], start=1):
        acc += coef_ref[k, i] * r[...].astype(jnp.float32)
    o_ref[...] = acc


def gossip_axpy_q8_flat(operands, coefs, *, block_rows: int | None = None,
                        interpret: bool = False):
    """Fused dequantize-and-combine  Σₖ wₖ·scaleₖ·qₖ  for int8 wire payloads.

    ``operands`` are (rows, 128) int8 post-permute payloads; ``coefs`` is a
    traced (n, rows // block_rows) f32 array of per-operand per-tile
    ``weight × scale`` products (computed outside: both are tiny).  Output
    is the decoded f32 mix.  Like :func:`gossip_axpy_flat`, the compiled
    kernel is keyed on arity and shape only.
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    operands = tuple(operands)
    rows, lane = operands[0].shape
    n_tiles = rows // block_rows
    coefs = jnp.asarray(coefs, jnp.float32).reshape(len(operands), n_tiles)
    assert lane == LANE and rows % block_rows == 0, (operands[0].shape,
                                                     block_rows)
    if not interpret:
        assert block_rows % 32 == 0, block_rows  # int8 min tile (32, 128)
    assert all(o.shape == operands[0].shape and o.dtype == jnp.int8
               for o in operands)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _axpy_q8_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)]
        + [spec] * len(operands),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(operands[0].shape, jnp.float32),
        interpret=interpret,
    )(coefs, *operands)
