"""Pallas TPU kernel: paged prefill-attention for chunked prefill.

One fixed-size prompt chunk of ONE slot (DESIGN §11): ``C`` query tokens
starting at absolute position ``chunk_start`` attend causally to (a) the
slot's **previously-filled pages**, read straight from the page pool
through page-table indirection exactly as in
:mod:`repro.kernels.paged_attention`, and (b) the **in-flight chunk's own
keys/values**, which at kernel time have not been scattered into the pool
yet (attend-then-write — in ring mode the chunk overwrites ring rows that
earlier chunk queries must still see) and therefore ride in as dense
``(K, C, hd)`` operands.

Grid = (kv_heads, n_pages + 1) with the kv axis innermost: steps
``j < n_pages`` are pool pages, the extra last step is the chunk block.
The online-softmax loop (running max / denominator / accumulator in VMEM
scratch) is the one from :mod:`repro.kernels.flash_attention`; the output
tile is written on the chunk step.

Masking:

* pool rows map to absolute key positions — identity in linear mode, the
  ring formula ``pos(r) = (start-1) - ((start-1-r) mod window)`` in ring
  mode — and a row is valid iff ``0 <= pos < chunk_start`` (the occupied
  ring prefix is ``[0, min(start, window))``);
* sliding-window masking ``pos > q_pos - window`` is applied
  **per element** — unlike the contiguous flash kernel it is NOT implied
  by block order, because a ring page mixes positions from two windows;
* chunk keys ``jk`` are causal within the chunk (``jk <= qi``) and
  ragged-masked by the traced ``chunk_len`` (the last chunk of a prompt
  is padded to the static width ``C``);
* fully-dead page blocks (``j*page_size >= min(start, window or inf)``)
  are skipped via ``pl.when``, and the k/v index map clamps the logical
  page index to the last *used* page-table entry, so the DMA never
  touches a page the allocator didn't assign to this slot (the
  masked-tail contract of DESIGN §10 — NaN-poison tested).

``chunk_start`` / ``chunk_len`` are scalar-prefetch data, not part of the
jit key: the whole serving trace reuses ONE compiled kernel regardless of
prompt-length distribution.

The dense oracle is :func:`repro.kernels.ref.paged_prefill_attention_ref`
(gather pages → positional sdpa); the jit'd public entry with
interpret-mode fallback is :func:`repro.kernels.ops.paged_prefill_attention`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_prefill_kernel_call"]

NEG_INF = -1e30


def _prefill_kernel(pt_ref, meta_ref, q_ref, kc_ref, vc_ref, kp_ref, vp_ref,
                    o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                    page_size: int, n_pages: int, chunk: int, group: int,
                    window: int):
    ji = pl.program_id(1)
    start = meta_ref[0]
    clen = meta_ref[1]
    prev = jnp.minimum(start, window) if window else start

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    rows = chunk * group

    def _online(s, mask, v):
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        # zero rows that are entirely masked (exp(NEG_INF-NEG_INF)=1 trap)
        row_live = jnp.any(mask, axis=1, keepdims=True)
        p = jnp.where(row_live, p, 0.0)
        alpha = jnp.where(row_live | (m_prev > NEG_INF / 2),
                          jnp.exp(m_prev - m_new), 0.0)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(jnp.logical_and(ji < n_pages, ji * page_size < prev))
    def _page_step():
        q = q_ref[0].astype(jnp.float32)                # (C*G, hd)
        k = kp_ref[0, :, 0, :].astype(jnp.float32)      # (page_size, hd)
        v = vp_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))  # (C*G, page_size)
        qi = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) // group
        r = ji * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        if window:
            # ring row r holds the NEWEST pre-chunk position congruent to
            # r mod window; unoccupied rows resolve to pos < 0
            kpos = (start - 1) - jnp.mod(start - 1 - r, window)
        else:
            kpos = r
        mask = (kpos >= 0) & (kpos < start) & (r < prev)
        if window:
            mask &= kpos > (start + qi) - window
        # zero never-written value rows: their probs are exactly 0, but
        # 0·NaN = NaN in the accumulator dot would leak pool poison
        col_dead = ~jnp.any(mask, axis=0)[:, None]      # (page_size, 1)
        v = jnp.where(col_dead, 0.0, v)
        _online(s, mask, v)

    @pl.when(ji == n_pages)
    def _chunk_step():
        q = q_ref[0].astype(jnp.float32)                # (C*G, hd)
        k = kc_ref[0].astype(jnp.float32)               # (C, hd)
        v = vc_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))  # (C*G, C)
        qi = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk), 0) // group
        jk = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk), 1)
        mask = (jk <= qi) & (jk < clen)
        if window:
            mask &= jk > qi - window
        _online(s, mask, v)

    @pl.when(ji == n_pages)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_prefill_kernel_call(q, k_chunk, v_chunk, k_pool, v_pool, pt_row,
                              meta, *, page_size: int, window: int = 0,
                              interpret: bool = False):
    """q: (K, C·G, hd) — chunk queries grouped by kv head, row ``i·G + g``
    is chunk token i, group member g; k_chunk, v_chunk: (K, C, hd) the
    in-flight chunk's keys/values (NOT yet in the pool); k_pool, v_pool:
    (num_pages, page_size, K, hd) page pools; pt_row: (n_pages,) int32 —
    ONE slot's page-table row; meta: (2,) int32 ``[chunk_start,
    chunk_len]``.  Returns (K, C·G, hd)."""
    K, CG, hd = q.shape
    C = k_chunk.shape[1]
    assert CG % C == 0, (q.shape, k_chunk.shape)
    G = CG // C
    n_pages = pt_row.shape[0]
    assert k_pool.shape[1] == page_size and k_pool.shape[2] == K, \
        (k_pool.shape, page_size, K)
    assert meta.shape == (2,), meta.shape

    def used(pt, meta_, j):
        # clamp to the last USED page-table entry (masked-tail contract):
        # pages past ceil(min(start, window)/page_size) were never written
        # by this slot and must not be fetched.  pt[0] is always a real
        # page — pages are reserved at admission (serve/paged_cache.py).
        prev = meta_[0] if not window else jnp.minimum(meta_[0], window)
        last = jnp.maximum(pl.cdiv(prev, page_size) - 1, 0)
        return pt[jnp.minimum(jnp.minimum(j, n_pages - 1), last)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(K, n_pages + 1),
        in_specs=[
            pl.BlockSpec((1, CG, hd), lambda k, j, pt, meta_: (k, 0, 0)),
            pl.BlockSpec((1, C, hd), lambda k, j, pt, meta_: (k, 0, 0)),
            pl.BlockSpec((1, C, hd), lambda k, j, pt, meta_: (k, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda k, j, pt, meta_: (used(pt, meta_, j), 0, k, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda k, j, pt, meta_: (used(pt, meta_, j), 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, CG, hd), lambda k, j, pt, meta_: (k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((CG, 1), jnp.float32),      # running max m
            pltpu.VMEM((CG, 1), jnp.float32),      # running denom l
            pltpu.VMEM((CG, hd), jnp.float32),     # output accumulator
        ],
    )
    kernel = functools.partial(_prefill_kernel, scale=hd ** -0.5,
                               page_size=page_size, n_pages=n_pages,
                               chunk=C, group=G, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(pt_row, meta, q, k_chunk, v_chunk, k_pool, v_pool)
