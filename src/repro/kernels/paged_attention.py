"""Pallas TPU kernel: paged decode-attention over a page-table KV cache.

One query token per slot (decode), keys/values gathered **directly from the
page pool** — no dense cache materialization.  The page table and per-slot
valid-row counts ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), so the k/v BlockSpec index maps can
compute the physical page for grid step ``(b, k, j)`` before the DMA is
issued: logical page ``j`` of slot ``b`` reads physical page
``page_table[b, j]``.  GQA head sharing mirrors the flash kernel — the
grid walks kv heads and each step processes that head's whole ``G``-query
group from one gathered page.

Grid = (slots, kv_heads, pages_per_slot) with the page axis innermost;
running max / denominator / accumulator live in VMEM scratch exactly as in
:mod:`repro.kernels.flash_attention`, and the output tile is written on the
last page step.

Safety contract (the masked-tail property, DESIGN §10):

* page-table entries past ``ceil(kv_len / page_size)`` are never read —
  the index map clamps the logical page index to the last *used* entry,
  so the DMA only ever touches pages the allocator assigned to this slot;
* rows past ``kv_len`` inside the last used page are masked to -inf
  before the online softmax (and fully-dead pages are skipped via
  ``pl.when``), so pool garbage can never leak into the output.

A slot with ``kv_len == 0`` (idle) produces a zero output tile — the
denominator clamp handles the all-masked case, no NaNs.

The dense oracle is :func:`repro.kernels.ref.paged_attention_ref` (gather
pages → ``sdpa_ref``); the jit'd public entry with interpret-mode fallback
is :func:`repro.kernels.ops.paged_attention`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_kernel_call"]

NEG_INF = -1e30


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  n_pages: int):
    b = pl.program_id(0)
    ji = pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[b]
    # a page is live iff it holds at least one valid row
    block_live = ji * page_size < kv_len

    @pl.when(block_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (page_size, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))  # (G, page_size)
        r = ji * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        s = jnp.where(r < kv_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        row_live = jnp.any(r < kv_len, axis=1, keepdims=True)
        p = jnp.where(row_live, p, 0.0)
        alpha = jnp.where(row_live | (m_prev > NEG_INF / 2),
                          jnp.exp(m_prev - m_new), 0.0)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ji == n_pages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_attention_kernel_call(q, k_pool, v_pool, page_table, kv_len, *,
                                page_size: int, interpret: bool = False):
    """q: (B, K, G, hd) — slot-batched single-token queries, grouped by kv
    head; k_pool, v_pool: (num_pages, page_size, K, hd) page pools;
    page_table: (B, n_pages) int32 physical-page ids; kv_len: (B,) int32
    valid KV rows per slot (ring mode: ``min(length, window)``).
    Returns (B, K, G, hd)."""
    B, K, G, hd = q.shape
    n_pages = page_table.shape[1]
    assert k_pool.shape[1] == page_size and k_pool.shape[2] == K, \
        (k_pool.shape, page_size, K)
    assert page_table.shape[0] == B and kv_len.shape == (B,), \
        (page_table.shape, kv_len.shape, B)

    def used(pt, ln, b, j):
        # clamp to the last USED page-table entry: entries past the valid
        # prefix are NULL and must never be fetched (masked-tail contract)
        last = jnp.maximum(pl.cdiv(ln[b], page_size) - 1, 0)
        return pt[b, jnp.minimum(j, last)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, k, j, pt, ln: (b, k, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, k, j, pt, ln: (used(pt, ln, b, j), 0, k, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, k, j, pt, ln: (used(pt, ln, b, j), 0, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, j, pt, ln: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),      # running max m
            pltpu.VMEM((G, 1), jnp.float32),      # running denom l
            pltpu.VMEM((G, hd), jnp.float32),     # output accumulator
        ],
    )
    kernel = functools.partial(_paged_kernel, scale=hd ** -0.5,
                               page_size=page_size, n_pages=n_pages)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(page_table, kv_len, q, k_pool, v_pool)
