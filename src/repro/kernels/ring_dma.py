"""Pallas TPU ring-collective gossip: remote-DMA permute fused into the
n-ary combine.

The §3 production path materializes every gossip term: each ``ppermute``
writes its neighbor payload to HBM, then ``gossip_axpy`` streams all of
them back in for the weighted combine — for a 3-term ring that is 2 full
extra HBM round-trips of the bus per step.  This kernel removes them for
the flat ±1 ring (the paper's experimental topology): each device streams
its own bus shard chunk-by-chunk through VMEM, ships each chunk to both
ring neighbors with ``pltpu.make_async_remote_copy`` (the guide's
ring-collective RDMA pattern), and accumulates

    out = w_c · x  +  w_l · x_left  +  w_r · x_right

directly in VMEM as chunks arrive — the permuted payloads never exist in
HBM, and the chunk (c+1) wire transfer overlaps the chunk-c combine.

Buffering/synchronization scheme (double-buffered, ack-gated):

* ``comm[dir, slot]`` — two VMEM landing slots per direction; chunk c
  lands in slot ``c % 2``.
* a chunk's RDMA for both directions is started one iteration ahead of
  its combine (prologue starts chunk 0 and 1), so one transfer is always
  in flight behind the compute;
* before re-using a landing slot (chunk c+2 overwrites chunk c's slot), a
  device must know BOTH neighbors consumed the chunk they received from
  it two iterations ago: after combining chunk c every device acks each
  neighbor on a **per-direction** semaphore (``ack[0]`` counts acks from
  the right neighbor for my dir-0 sends, ``ack[1]`` from the left for my
  dir-1 sends), and ``start(c+2)`` first waits ONE ack on each — by
  induction the cumulative count then proves that specific neighbor
  consumed through chunk c.  A single shared counter could not attribute
  acks to a neighbor (a fast right neighbor's two acks would unblock a
  send into the slow left neighbor's busy slot — the classic 2-slot ring
  race);
* a barrier semaphore handshake with both neighbors runs once at kernel
  entry so no device issues an RDMA into a peer that has not yet entered
  the kernel.

This is TPU-only by construction (remote DMA does not exist off-TPU and
is not interpretable on CPU): :func:`ring_dma_supported` returns False
unless the backend is a real TPU, and ``core/mixing.py`` then falls back
to the shard_map + ``ppermute`` + ``gossip_axpy`` path, which this kernel
is pinned against (same math, :func:`ring_combine_reference`).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .edm_update import BLOCK_ROWS, LANE

__all__ = ["ring_plan", "ring_dma_supported", "ring_combine_shard",
           "ring_combine_reference", "on_tpu"]


def on_tpu() -> bool:
    """Real-TPU check: remote DMA has no CPU interpret path."""
    return jax.default_backend() == "tpu"


def ring_plan(topo) -> Optional[Tuple[float, float, float]]:
    """Collapse ``topo``'s shift terms into ring-combine weights
    ``(w_center, w_from_left, w_from_right)`` — or None when the topology
    is not a flat ±1 ring (any grid-level term or a longer-range shift
    disqualifies it; the shifts are normalized mod n, so n−1 ≡ −1).

    Roll semantics map shifts to wire directions: a ``+1`` term is
    ``x_new[i] = x[i−1]`` — device i *receives from its left neighbor* —
    and ``−1`` receives from the right.
    """
    n = topo.n_agents
    w = {0: 0.0, 1: 0.0, -1: 0.0}
    for t in topo.terms:
        if t.level != "flat":
            return None
        s = t.shift % n
        if s == 0:
            w[0] += t.weight
        elif s == 1:
            w[1] += t.weight
        elif s == n - 1:
            w[-1] += t.weight
        else:
            return None
    return (float(w[0]), float(w[1]), float(w[-1]))


def ring_dma_supported(topo, *, n_axes: int = 1, B: int = 1,
                       backend: Optional[str] = None) -> bool:
    """True iff the remote-DMA ring kernel can carry ``topo``'s gossip:
    flat ±1 ring, one agent per device (B = 1) on a single flat mesh axis,
    ≥ 2 devices, and a real TPU backend (see module docstring — off-TPU
    the engine falls back to ppermute)."""
    if backend is None:
        backend = jax.default_backend()
    return (backend == "tpu" and n_axes == 1 and B == 1
            and topo.n_agents >= 2 and ring_plan(topo) is not None)


def ring_combine_reference(x, plan, axis_name: str):
    """jnp oracle for one shard (inside shard_map): the same combine via
    ``lax.ppermute`` — the fallback path and the kernel's allclose target."""
    w_c, w_l, w_r = plan
    n = jax.lax.psum(1, axis_name)
    from_left = jax.lax.ppermute(
        x, axis_name, [((d - 1) % n, d) for d in range(n)])
    from_right = jax.lax.ppermute(
        x, axis_name, [((d + 1) % n, d) for d in range(n)])
    return w_c * x + w_l * from_left + w_r * from_right


# ---------------------------------------------------------------------------
# the kernel (TPU only — pragma: no cover in this CPU container)
# ---------------------------------------------------------------------------

def _ring_kernel(w_ref, x_ref, o_ref, xbuf, obuf, comm, load_sem, store_sem,
                 send_sem, recv_sem, ack_sem, *, axis_name: str, n_dev: int,
                 n_chunks: int, chunk_rows: int):  # pragma: no cover - TPU
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    my = jax.lax.axis_index(axis_name)
    right = jax.lax.rem(my + 1, n_dev)
    left = jax.lax.rem(my + n_dev - 1, n_dev)

    # entry barrier: both neighbors are inside the kernel before any RDMA
    # may land in their comm buffers.
    barrier = pltpu.get_barrier_semaphore()
    pltpu.semaphore_signal(barrier, inc=1, device_id=(left,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_signal(barrier, inc=1, device_id=(right,),
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(barrier, 2)

    def load(c):
        """HBM → VMEM staging of my chunk c (src of both outgoing RDMAs)."""
        slot = jax.lax.rem(c, 2)
        cp = pltpu.make_async_copy(
            x_ref.at[pl.ds(c * chunk_rows, chunk_rows), :],
            xbuf.at[slot], load_sem.at[slot])
        cp.start()
        cp.wait()

    def start(c):
        """Ship my staged chunk c to both neighbors' landing slots."""
        slot = jax.lax.rem(c, 2)
        # to my right neighbor, landing as THEIR from-left payload (dir 0)
        pltpu.make_async_remote_copy(
            src_ref=xbuf.at[slot], dst_ref=comm.at[0, slot],
            send_sem=send_sem.at[0, slot], recv_sem=recv_sem.at[0, slot],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL).start()
        # to my left neighbor, landing as THEIR from-right payload (dir 1)
        pltpu.make_async_remote_copy(
            src_ref=xbuf.at[slot], dst_ref=comm.at[1, slot],
            send_sem=send_sem.at[1, slot], recv_sem=recv_sem.at[1, slot],
            device_id=(left,),
            device_id_type=pltpu.DeviceIdType.LOGICAL).start()

    load(0)
    start(0)

    @pl.when(n_chunks > 1)
    def _():
        load(1)
        start(1)

    def body(c, _):
        slot = jax.lax.rem(c, 2)
        # my outgoing chunk c left the staging buffer, and both neighbor
        # payloads of chunk c have landed (SPMD symmetry: my recv_sem is
        # signaled by the matching remote sends of my neighbors).
        pltpu.semaphore_wait(send_sem.at[0, slot], 1)
        pltpu.semaphore_wait(send_sem.at[1, slot], 1)
        pltpu.semaphore_wait(recv_sem.at[0, slot], 1)
        pltpu.semaphore_wait(recv_sem.at[1, slot], 1)
        acc = (w_ref[0, 0] * xbuf[slot].astype(jnp.float32)
               + w_ref[0, 1] * comm[0, slot].astype(jnp.float32)
               + w_ref[0, 2] * comm[1, slot].astype(jnp.float32))
        obuf[slot] = acc.astype(obuf.dtype)
        st = pltpu.make_async_copy(
            obuf.at[slot], o_ref.at[pl.ds(c * chunk_rows, chunk_rows), :],
            store_sem.at[slot])
        st.start()
        # tell each neighbor its chunk c landed AND was consumed — my
        # landing slot c%2 for that direction is free for its chunk c+2.
        # My comm[0] receives the LEFT neighbor's dir-0 sends → ack its
        # ack[0]; my comm[1] receives the RIGHT neighbor's dir-1 sends.
        pltpu.semaphore_signal(ack_sem.at[0], inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_signal(ack_sem.at[1], inc=1, device_id=(right,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)

        @pl.when(c + 2 < n_chunks)
        def _():
            # EACH neighbor must have consumed chunk c before chunk c+2
            # may overwrite its slot c%2: one ack per direction here makes
            # the cumulative per-direction count c+1 = chunks 0..c — and
            # my own staging / output slots must have drained.
            pltpu.semaphore_wait(ack_sem.at[0], 1)
            pltpu.semaphore_wait(ack_sem.at[1], 1)
            pltpu.semaphore_wait(store_sem.at[slot], 1)
            load(c + 2)
            start(c + 2)
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)
    # final drain: every chunk acked by both neighbors (semaphores must end
    # at zero across pallas_calls sharing a collective_id); stores done.
    pltpu.semaphore_wait(ack_sem.at[0], min(2, n_chunks))
    pltpu.semaphore_wait(ack_sem.at[1], min(2, n_chunks))
    pltpu.semaphore_wait(store_sem.at[jax.lax.rem(n_chunks - 1, 2)], 1)

    @pl.when(n_chunks > 1)
    def _():
        pltpu.semaphore_wait(store_sem.at[jax.lax.rem(n_chunks, 2)], 1)


def ring_combine_shard(x, plan, *, axis_name: str, n_devices: int,
                       chunk_rows: int | None = None,
                       collective_id: int = 7):
    """Fused permute+combine of one bus shard — call INSIDE a shard_map
    body whose mesh axis ``axis_name`` carries one agent per device.

    ``x``: this shard's ``(1, rows, 128)`` (or ``(rows, 128)``) bus block;
    ``plan``: :func:`ring_plan` weights.  Returns the combined shard with
    the same shape.  TPU only (:func:`ring_dma_supported`).
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    w_c, w_l, w_r = plan
    lead = x.ndim == 3
    xs = x.reshape(x.shape[-2:]) if lead else x
    rows, lane = xs.shape
    assert lane == LANE, xs.shape
    if chunk_rows is None:
        # largest divisor of rows that fits the kernel tile budget: both
        # rows (bus layout contract) and BLOCK_ROWS are multiples of 8, so
        # gcd >= 8 always divides rows — a retuned REPRO_BLOCK_ROWS can
        # never strand the transport on a valid bus.
        chunk_rows = math.gcd(rows, BLOCK_ROWS)
    assert chunk_rows % 8 == 0 and rows % chunk_rows == 0, (rows, chunk_rows)
    n_chunks = rows // chunk_rows
    w = jnp.asarray([[w_c, w_l, w_r]], jnp.float32)

    out = pl.pallas_call(  # pragma: no cover - requires TPU
        functools.partial(_ring_kernel, axis_name=axis_name,
                          n_dev=n_devices, n_chunks=n_chunks,
                          chunk_rows=chunk_rows),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((rows, lane), xs.dtype),
        scratch_shapes=[
            pltpu.VMEM((2, chunk_rows, lane), xs.dtype),   # xbuf staging
            pltpu.VMEM((2, chunk_rows, lane), xs.dtype),   # obuf staging
            pltpu.VMEM((2, 2, chunk_rows, lane), xs.dtype),  # comm[dir,slot]
            pltpu.SemaphoreType.DMA((2,)),                 # load_sem
            pltpu.SemaphoreType.DMA((2,)),                 # store_sem
            pltpu.SemaphoreType.DMA((2, 2)),               # send_sem
            pltpu.SemaphoreType.DMA((2, 2)),               # recv_sem
            pltpu.SemaphoreType.REGULAR((2,)),             # ack_sem per dir
        ],
        compiler_params=pltpu.TPUCompilerParams(
            collective_id=collective_id, has_side_effects=True),
    )(w, xs)
    return out.reshape(x.shape) if lead else out
