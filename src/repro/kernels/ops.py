"""jit'd public wrappers around the Pallas kernels.

On a real TPU these run compiled; in this CPU container they execute in
interpret mode (functionally identical, exercised by the kernel test suite).
``edm_update_tree`` is the pytree-level entry the EDM optimizer uses when
``use_fused_kernel=True``.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .edm_update import (BLOCK_ROWS, LANE, edm_update_flat,
                         edm_update_ef_flat, gossip_axpy_flat,
                         gossip_axpy_q8_flat)
from .flash_attention import flash_attention_kernel_call
from .paged_attention import paged_attention_kernel_call
from .paged_prefill import paged_prefill_kernel_call

__all__ = ["edm_update", "edm_update_tree", "edm_update_bus",
           "edm_update_bus_ef", "gossip_axpy", "gossip_axpy_wire",
           "flash_attention", "paged_attention", "paged_prefill_attention",
           "padded_size"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def padded_size(n: int, block_rows: int | None = None) -> int:
    """Elements ``_pack`` actually streams for an ``n``-element array: padded
    up to a whole number of (block_rows, 128) grid tiles.  This is the
    per-leaf pad waste the packed bus amortizes (DESIGN §5) and the number
    the benchmarks' modeled-bytes columns must use — modeling with the
    logical ``n`` undercounts kernel HBM traffic per leaf."""
    if block_rows is None:
        block_rows = BLOCK_ROWS
    tile = block_rows * LANE
    return -(-n // tile) * tile


def _pack(leaf, block_rows, dtype=jnp.float32):
    """Flatten to (rows, LANE), padded; ``dtype=None`` keeps the leaf dtype
    (bf16 gossip payloads stay bf16 on the wire and in VMEM)."""
    flat = leaf.reshape(-1)
    if dtype is not None:
        flat = flat.astype(dtype)
    n = flat.size
    pad = padded_size(n, block_rows) - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, LANE), n


def _unpack(packed, n, shape, dtype):
    return packed.reshape(-1)[:n].reshape(shape).astype(dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "block_rows",
                                             "interpret"))
def edm_update(x, g, m, psi, *, alpha: float, beta: float,
               block_rows: int | None = None, interpret: bool | None = None):
    """Array-level fused EDM update.  Any shape; returns (m', ψ', φ).

    ``block_rows`` defaults to the REPRO_BLOCK_ROWS-tunable
    :data:`~repro.kernels.edm_update.BLOCK_ROWS` (the real-TPU sweep knob).
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    if interpret is None:
        interpret = not _on_tpu()
    xp, n = _pack(x, block_rows)
    gp, _ = _pack(g, block_rows)
    mp, _ = _pack(m, block_rows)
    pp, _ = _pack(psi, block_rows)
    m2, psi2, phi = edm_update_flat(xp, gp, mp, pp, alpha=alpha, beta=beta,
                                    block_rows=block_rows, interpret=interpret)
    return (_unpack(m2, n, x.shape, m.dtype),
            _unpack(psi2, n, x.shape, psi.dtype),
            _unpack(phi, n, x.shape, x.dtype))


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "block_rows",
                                             "interpret"))
def edm_update_bus(x, g, m, psi, *, alpha: float, beta: float,
                   block_rows: int | None = None,
                   interpret: bool | None = None):
    """Bus-resident fused EDM update: ONE ``pallas_call`` over the whole
    ``(A, rows, 128)`` superbuffer (DESIGN §5), vs one per leaf for
    :func:`edm_update_tree`.  The bus layout already pads ``rows`` to a
    multiple of ``block_rows`` and aligns every leaf to the 8×128 tile, so
    no packing happens here — the buffers are griddable as-is.
    Returns ``(m', ψ', φ)`` in bus layout."""
    if block_rows is None:
        block_rows = BLOCK_ROWS
    if interpret is None:
        interpret = not _on_tpu()
    A, rows, lane = x.shape
    assert lane == LANE and (A * rows) % block_rows == 0, (x.shape, block_rows)
    flat = lambda b: b.reshape(A * rows, LANE)
    m2, psi2, phi = edm_update_flat(flat(x), flat(g), flat(m), flat(psi),
                                    alpha=alpha, beta=beta,
                                    block_rows=block_rows,
                                    interpret=interpret)
    return (m2.reshape(x.shape), psi2.reshape(x.shape), phi.reshape(x.shape))


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "fmt",
                                             "block_rows", "interpret"))
def edm_update_bus_ef(x, g, m, psi, e, *, alpha: float, beta: float,
                      fmt: str, block_rows: int | None = None,
                      interpret: bool | None = None):
    """Bus-resident fused EDM update **with error-feedback quantization**
    (DESIGN §9): one pallas_call computes m', ψ', the wire payload
    ``Q(φ + e)`` and the next residual ``e' = (φ + e) − decode(Q(φ + e))``
    in a single pass over the ``(A, rows, 128)`` superbuffer — quantize and
    residual-update share the VMEM tile, no extra HBM round trips.

    Returns ``(m', ψ', payload, e')`` where ``payload`` is the wire-format
    pytree of :class:`repro.core.wire.WireCodec`: a bf16 bus for
    ``fmt="bf16"``, ``(q int8 bus, (A, rows // block_rows) f32 scales)``
    for ``fmt="int8"``.  The bus layout quantizes rows to a multiple of
    ``block_rows × shards``, so under ``agents="pod"`` each shard's row
    block holds whole scale blocks and this runs shard-locally unchanged.
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    if interpret is None:
        interpret = not _on_tpu()
    A, rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0, (x.shape, block_rows)
    flat = lambda b: b.reshape(A * rows, LANE)
    outs = edm_update_ef_flat(flat(x), flat(g), flat(m), flat(psi), flat(e),
                              alpha=alpha, beta=beta, fmt=fmt,
                              block_rows=block_rows, interpret=interpret)
    if fmt == "bf16":
        m2, psi2, q, e2 = outs
        payload = q.reshape(x.shape)
    else:
        m2, psi2, q, scale, e2 = outs
        payload = (q.reshape(x.shape),
                   scale.reshape(A, rows // block_rows))
    return (m2.reshape(x.shape), psi2.reshape(x.shape), payload,
            e2.reshape(x.shape))


def edm_update_tree(params: Any, grads: Any, m: Any, psi: Any, *,
                    alpha: float, beta: float) -> Tuple[Any, Any, Any]:
    """Pytree-level fused update: returns (m', φ, ψ') trees (optimizer order)."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(m)
    flat_s = treedef.flatten_up_to(psi)
    outs = [edm_update(x, g, mm, ss, alpha=alpha, beta=beta)
            for x, g, mm, ss in zip(flat_p, flat_g, flat_m, flat_s)]
    m_new = treedef.unflatten([o[0] for o in outs])
    psi_new = treedef.unflatten([o[1] for o in outs])
    phi = treedef.unflatten([o[2] for o in outs])
    return m_new, phi, psi_new


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "out_dtype"))
def _gossip_axpy_jit(operands, weights, block_rows, interpret,
                     out_dtype=None):
    first = operands[0]
    packed = [_pack(o, block_rows, dtype=None)[0] for o in operands]
    n = first.size
    out = gossip_axpy_flat(packed, weights, block_rows=block_rows,
                           interpret=interpret, out_dtype=out_dtype)
    return _unpack(out, n, first.shape,
                   first.dtype if out_dtype is None else out_dtype)


def gossip_axpy(operands, weights, *, block_rows: int | None = None,
                interpret: bool | None = None):
    """n-ary fused gossip combine  Σₖ wₖ·operandₖ  for arbitrary-shape arrays.

    All operands must share one shape and dtype (f32 or bf16).  This is the
    array-level entry the ppermute mixing engine calls once per leaf after
    its collective-permutes (DESIGN §3).  ``weights`` are traced data, not
    part of the jit key: a time-varying schedule whose rounds share an arity
    reuses one compiled kernel across rounds (DESIGN §4), and distinct
    arities each compile exactly once.  ``block_rows`` (default: env-tunable
    :data:`~repro.kernels.edm_update.BLOCK_ROWS`) is the TPU tuning knob.
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    if interpret is None:
        interpret = not _on_tpu()
    return _gossip_axpy_jit(tuple(operands),
                            jnp.asarray(weights, jnp.float32),
                            block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("fmt", "block_rows",
                                             "interpret"))
def _gossip_axpy_wire_jit(payloads, weights, fmt, block_rows, interpret):
    if fmt in ("f32", "bf16"):
        # bf16 wire: accumulate f32 in-kernel, store the mixed bus f32 —
        # the decode is the astype the axpy kernel already performs.
        return _gossip_axpy_jit(payloads, weights, block_rows, interpret,
                                out_dtype=jnp.float32)
    qs, scales = zip(*payloads)
    first = qs[0]
    flat_qs = tuple(q.reshape(-1, LANE) for q in qs)
    # (n, n_tiles) weight × per-tile-scale products: scales flatten in the
    # same (agent-major) order the flattened bus tiles do, because rows is
    # a multiple of block_rows per agent.
    coefs = (jnp.asarray(weights, jnp.float32)[:, None]
             * jnp.stack([s.reshape(-1) for s in scales]))
    out = gossip_axpy_q8_flat(flat_qs, coefs, block_rows=block_rows,
                              interpret=interpret)
    return out.reshape(first.shape)


def gossip_axpy_wire(payloads, weights, *, fmt: str,
                     block_rows: int | None = None,
                     interpret: bool | None = None):
    """Fused decode-and-combine for wire-format gossip payloads
    (DESIGN §9): ``Σₖ wₖ · decode(payloadₖ)`` with the dequantize folded
    into the n-ary combine — int8/bf16 payloads widen to f32 exactly once,
    inside the kernel, and the mixed bus comes out f32.

    ``payloads`` are post-permute :class:`~repro.core.wire.WireCodec`
    payloads of one arity: f32/bf16 arrays, or ``(q, scale)`` pairs whose
    ``scale`` carries one f32 per ``(block_rows, 128)`` block in tile
    order.  ``weights`` are traced data, as in :func:`gossip_axpy`.
    """
    if block_rows is None:
        block_rows = BLOCK_ROWS
    if interpret is None:
        interpret = not _on_tpu()
    return _gossip_axpy_wire_jit(tuple(payloads),
                                 jnp.asarray(weights, jnp.float32),
                                 fmt, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "blk_q",
                                             "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool | None = None):
    """Flash GQA attention, (B, H, S, hd) layout."""
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_kernel_call(q, k, v, causal=causal, window=window,
                                       blk_q=blk_q, blk_k=blk_k,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_attention(q, k_pool, v_pool, page_table, kv_len, *,
                    page_size: int, interpret: bool | None = None):
    """Paged decode-attention (DESIGN §10): q (B, K, G, hd) slot-batched
    single-token queries against (num_pages, page_size, K, hd) page pools,
    gathered through a (B, n_pages) page table with per-slot ``kv_len``
    masking.  Oracle: :func:`repro.kernels.ref.paged_attention_ref`."""
    if interpret is None:
        interpret = not _on_tpu()
    return paged_attention_kernel_call(q, k_pool, v_pool, page_table, kv_len,
                                       page_size=page_size,
                                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("page_size", "window",
                                             "interpret"))
def paged_prefill_attention(q, k_chunk, v_chunk, k_pool, v_pool, pt_row,
                            chunk_start, chunk_len, *, page_size: int,
                            window: int = 0, interpret: bool | None = None):
    """Paged prefill-attention for one chunk of one slot (DESIGN §11).

    Model layout in and out: q (1, C, H, hd) chunk queries, k_chunk /
    v_chunk (1, C, K, hd) the in-flight chunk's keys/values (not yet
    scattered into the pool), pools (num_pages, page_size, K, hd),
    pt_row (n_pages,) the slot's page-table row.  ``chunk_start`` /
    ``chunk_len`` are traced int32 scalars — NOT part of the jit key, so
    every chunk of every prompt length reuses one compiled kernel.
    Oracle: :func:`repro.kernels.ref.paged_prefill_attention_ref`."""
    if interpret is None:
        interpret = not _on_tpu()
    _, C, H, hd = q.shape
    K = k_chunk.shape[2]
    G = H // K
    # (1, C, H, hd) -> (K, C*G, hd), row i*G + g = (token i, group member g)
    qk = (q.reshape(C, K, G, hd).transpose(1, 0, 2, 3).reshape(K, C * G, hd))
    kc = k_chunk[0].transpose(1, 0, 2)           # (K, C, hd)
    vc = v_chunk[0].transpose(1, 0, 2)
    meta = jnp.stack([jnp.asarray(chunk_start, jnp.int32),
                      jnp.asarray(chunk_len, jnp.int32)])
    out = paged_prefill_kernel_call(qk, kc, vc, k_pool, v_pool,
                                    jnp.asarray(pt_row, jnp.int32), meta,
                                    page_size=page_size, window=window,
                                    interpret=interpret)
    return (out.reshape(K, C, G, hd).transpose(1, 0, 2, 3)
            .reshape(1, C, H, hd))
