"""repro.kernels — Pallas TPU kernels for the perf-critical hot spots:
fused EDM optimizer update + gossip combine, and flash GQA attention."""
from . import ops, ref  # noqa: F401
