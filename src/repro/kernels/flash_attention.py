"""Pallas TPU kernel: blockwise online-softmax (flash) GQA attention.

Causal and sliding-window masking; grouped-query head sharing via the k/v
BlockSpec index map (q head h reads kv head h // group_size — no materialized
K/V replication).  Grid = (batch, q_heads, Sq/blk_q, Sk/blk_k) with the kv
axis innermost; running max / denominator / accumulator live in VMEM scratch
and the output tile is written on the last kv step.

Block shapes default to 128×128 — MXU-aligned, and the working set
(q 128×hd + k/v 2×128×hd + acc 128×hd + s 128×128, f32) ≈ 0.4 MB for hd=128,
far inside the ~16 MB VMEM budget; larger blk_k amortizes loop overhead for
long-context prefill.

The sliding-window variant is the sub-quadratic path that makes dense-arch
``long_500k`` decode admissible (DESIGN §2): FLOPs scale with window, not
context, and fully-masked blocks are skipped entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  blk_q: int, blk_k: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window

    # skip fully-masked blocks (the flash win for causal/sliding-window)
    block_live = True
    if causal:
        block_live = ki * blk_k <= qi * blk_q + blk_q - 1
    if window:
        block_live = jnp.logical_and(
            block_live, (ki + 1) * blk_k - 1 > qi * blk_q - window)

    @pl.when(block_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (blk_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (blk_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())))
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        # zero out rows that are entirely masked (exp(NEG_INF-NEG_INF)=1 trap)
        row_live = jnp.any(mask, axis=1, keepdims=True)
        p = jnp.where(row_live, p, 0.0)
        alpha = jnp.where(row_live | (m_prev > NEG_INF / 2),
                          jnp.exp(m_prev - m_new), 0.0)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel_call(q, k, v, *, causal: bool = True,
                                window: int = 0, blk_q: int = 128,
                                blk_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, K, Sk, hd); H % K == 0.
    Returns (B, H, Sq, hd).  Sq % blk_q == 0, Sk % blk_k == 0."""
    B, H, Sq, hd = q.shape
    K, Sk = k.shape[1], k.shape[2]
    assert H % K == 0 and Sq % blk_q == 0 and Sk % blk_k == 0
    G = H // K
    n_kv = Sk // blk_k
    grid = (B, H, Sq // blk_q, n_kv)

    q_spec = pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, blk_k, hd), lambda b, h, i, j: (b, h // G, j, 0))
    o_spec = pl.BlockSpec((1, 1, blk_q, hd), lambda b, h, i, j: (b, h, i, 0))

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_kv_blocks=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),     # running max m
            pltpu.VMEM((blk_q, 1), jnp.float32),     # running denom l
            pltpu.VMEM((blk_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
