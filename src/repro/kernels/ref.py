"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import paged_prefill_sdpa, sdpa_ref

__all__ = ["edm_update_ref", "gossip_axpy_ref", "flash_attention_ref",
           "gather_pages", "paged_attention_ref",
           "paged_prefill_attention_ref"]


def edm_update_ref(x, g, m, psi, *, alpha: float, beta: float):
    """Reference EDM fused-update chain (optimizers.make_edm unfused path)."""
    m_new = beta * m + (1.0 - beta) * g
    psi_new = x - alpha * m_new
    phi = psi_new + x - psi
    return m_new, psi_new, phi


def gossip_axpy_ref(operands, weights):
    """n-ary combine  Σₖ wₖ·operandₖ  with f32 accumulation (matches the
    kernel's bf16 path: one rounding, on the final store)."""
    acc = sum(w * o.astype(jnp.float32) for w, o in zip(weights, operands))
    return acc.astype(operands[0].dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Sq, hd); k, v: (B, K, Sk, hd) — delegates to the model-level
    SDPA oracle (which is itself validated by the serving tests)."""
    out = sdpa_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                   jnp.moveaxis(v, 1, 2), causal=causal, window=window)
    return jnp.moveaxis(out, 2, 1)


def gather_pages(pool, page_table):
    """Dense view of a paged pool: (num_pages, page_size, K, hd) gathered
    through a (B, n_pages) page table → (B, n_pages·page_size, K, hd).
    Row ``j·page_size + r`` of slot b is row r of physical page
    ``page_table[b, j]`` — the layout the page allocator maintains."""
    B, n_pages = page_table.shape
    _, page_size, K, hd = pool.shape
    dense = jnp.take(pool, page_table.reshape(-1), axis=0)
    return dense.reshape(B, n_pages * page_size, K, hd)


def paged_attention_ref(q, k_pool, v_pool, page_table, kv_len, *,
                        page_size: int):
    """Dense oracle for the paged decode-attention kernel: gather each
    slot's pages into a contiguous cache and run the model-level SDPA
    oracle with per-slot valid-length masking.  q: (B, K, G, hd) grouped
    single-token queries (the kernel's layout); returns (B, K, G, hd).

    This is also the op sequence the serving engine's ``attn_impl="ref"``
    path executes — the engine-vs-dense divergence gate compares two
    runs of these exact ops (paged gather vs contiguous cache), so it
    asserts EXACT equality (DESIGN §10)."""
    B, K, G, hd = q.shape
    assert k_pool.shape[1] == page_size, (k_pool.shape, page_size)
    k = gather_pages(k_pool, page_table)
    v = gather_pages(v_pool, page_table)
    out = sdpa_ref(q.reshape(B, 1, K * G, hd), k, v, causal=False,
                   kv_len=kv_len)
    return out.reshape(B, K, G, hd)


def paged_prefill_attention_ref(q, k_chunk, v_chunk, k_pool, v_pool, pt_row,
                                chunk_start, chunk_len, *, window: int = 0):
    """Dense oracle for the paged prefill-attention kernel (DESIGN §11):
    gather the slot's pages, concatenate the in-flight chunk's dense
    keys/values, and run the positional SDPA oracle with ring-aware
    key positions and per-element window masking.  q: (1, C, H, hd)
    model-layout chunk queries; k_chunk, v_chunk: (1, C, K, hd);
    pt_row: (n_pages,); returns (1, C, H, hd).

    This is also the op sequence ``attn_impl="ref"`` executes inside the
    chunked serving engine (:func:`repro.models.attention.paged_prefill_sdpa`
    — same function), so the engine-vs-oracle gate is exact equality."""
    return paged_prefill_sdpa(q, k_chunk, v_chunk, k_pool, v_pool, pt_row,
                              chunk_start, chunk_len, window=window)
