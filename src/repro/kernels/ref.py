"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import sdpa_ref

__all__ = ["edm_update_ref", "gossip_axpy_ref", "flash_attention_ref"]


def edm_update_ref(x, g, m, psi, *, alpha: float, beta: float):
    """Reference EDM fused-update chain (optimizers.make_edm unfused path)."""
    m_new = beta * m + (1.0 - beta) * g
    psi_new = x - alpha * m_new
    phi = psi_new + x - psi
    return m_new, psi_new, phi


def gossip_axpy_ref(operands, weights):
    """n-ary combine  Σₖ wₖ·operandₖ  with f32 accumulation (matches the
    kernel's bf16 path: one rounding, on the final store)."""
    acc = sum(w * o.astype(jnp.float32) for w, o in zip(weights, operands))
    return acc.astype(operands[0].dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Sq, hd); k, v: (B, K, Sk, hd) — delegates to the model-level
    SDPA oracle (which is itself validated by the serving tests)."""
    out = sdpa_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                   jnp.moveaxis(v, 1, 2), causal=causal, window=window)
    return jnp.moveaxis(out, 2, 1)
