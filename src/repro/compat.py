"""Cross-version jax shims.

The codebase targets the ``jax.shard_map`` API (jax >= 0.8, ``check_vma``)
but must also run on the 0.4.x line shipped in the CPU test container,
where the entry point is ``jax.experimental.shard_map.shard_map`` and the
replication check is spelled ``check_rep``.  Everything that shard-maps
(gossip engines, expert-parallel MoE) goes through this one wrapper so the
version split lives in exactly one place.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking disabled, on any jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
